//! Reader for the tensor bundles written by `python/compile/io_bin.py`.
//!
//! A bundle is `<prefix>.bin` (raw little-endian payloads) + `<prefix>.json`
//! (manifest with name/dtype/shape/offset per tensor).  See io_bin.py for
//! the writer; `test_datasets.py::test_bundle_roundtrip` covers the Python
//! side, the tests here cover cross-language decoding.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use crate::util::json::Json;

/// Everything that can go wrong decoding a bundle.
#[derive(Debug)]
pub enum BinError {
    Io(std::io::Error),
    Manifest(String),
    NotFound(String),
    Dtype {
        name: String,
        actual: String,
        wanted: String,
    },
}

impl fmt::Display for BinError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BinError::Io(e) => write!(f, "io error reading bundle: {e}"),
            BinError::Manifest(m) => write!(f, "manifest error: {m}"),
            BinError::NotFound(n) => write!(f, "tensor '{n}' not found in bundle"),
            BinError::Dtype {
                name,
                actual,
                wanted,
            } => write!(f, "tensor '{name}' has dtype {actual}, wanted {wanted}"),
        }
    }
}

impl std::error::Error for BinError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            BinError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for BinError {
    fn from(e: std::io::Error) -> Self {
        BinError::Io(e)
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I8 { shape: Vec<usize>, data: Vec<i8> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. }
            | Tensor::I8 { shape, .. }
            | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        // a tensor is empty iff any dimension is zero
        self.shape().contains(&0)
    }

    /// Any numeric tensor widened to f32 (i8 ternary weights included).
    pub fn to_f32(&self) -> Vec<f32> {
        match self {
            Tensor::F32 { data, .. } => data.clone(),
            Tensor::I8 { data, .. } => data.iter().map(|&v| v as f32).collect(),
            Tensor::I32 { data, .. } => data.iter().map(|&v| v as f32).collect(),
        }
    }
}

/// A loaded bundle: manifest metadata + tensors by name.
pub struct Bundle {
    pub meta: Json,
    pub tensors: BTreeMap<String, Tensor>,
}

impl Bundle {
    pub fn load(prefix: &Path) -> Result<Bundle, BinError> {
        let manifest_path = prefix.with_extension("json");
        let bin_path = prefix.with_extension("bin");
        let text = std::fs::read_to_string(&manifest_path)?;
        let manifest = Json::parse(&text)
            .map_err(|e| BinError::Manifest(format!("{manifest_path:?}: {e}")))?;
        let raw = std::fs::read(&bin_path)?;

        let entries = manifest
            .get("tensors")
            .and_then(|t| t.as_arr())
            .ok_or_else(|| BinError::Manifest("missing 'tensors'".into()))?;

        let mut tensors = BTreeMap::new();
        for e in entries {
            let name = e
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or_else(|| BinError::Manifest("tensor without name".into()))?
                .to_string();
            let dtype = e.get("dtype").and_then(|d| d.as_str()).unwrap_or("f32");
            let shape = e
                .get("shape")
                .and_then(|s| s.usize_vec())
                .ok_or_else(|| BinError::Manifest(format!("{name}: bad shape")))?;
            let offset = e.get("offset").and_then(|o| o.as_usize()).unwrap_or(0);
            let nbytes = e.get("nbytes").and_then(|o| o.as_usize()).unwrap_or(0);
            if offset + nbytes > raw.len() {
                return Err(BinError::Manifest(format!(
                    "{name}: extent {}..{} beyond payload {}",
                    offset,
                    offset + nbytes,
                    raw.len()
                )));
            }
            let bytes = &raw[offset..offset + nbytes];
            let t = match dtype {
                "f32" => Tensor::F32 {
                    shape,
                    data: bytes
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                },
                "i8" => Tensor::I8 {
                    shape,
                    data: bytes.iter().map(|&b| b as i8).collect(),
                },
                "i32" => Tensor::I32 {
                    shape,
                    data: bytes
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                },
                other => {
                    return Err(BinError::Manifest(format!(
                        "{name}: unknown dtype {other}"
                    )))
                }
            };
            if t.len() * dtype_size(dtype) != nbytes {
                return Err(BinError::Manifest(format!(
                    "{name}: shape/nbytes mismatch"
                )));
            }
            tensors.insert(name, t);
        }
        Ok(Bundle {
            meta: manifest.get("meta").cloned().unwrap_or(Json::Null),
            tensors,
        })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor, BinError> {
        self.tensors
            .get(name)
            .ok_or_else(|| BinError::NotFound(name.to_string()))
    }

    pub fn f32(&self, name: &str) -> Result<(&[usize], Vec<f32>), BinError> {
        let t = self.get(name)?;
        Ok((t.shape(), t.to_f32()))
    }

    pub fn i8(&self, name: &str) -> Result<(&[usize], &[i8]), BinError> {
        match self.get(name)? {
            Tensor::I8 { shape, data } => Ok((shape, data)),
            t => Err(BinError::Dtype {
                name: name.into(),
                actual: format!("{t:?}").chars().take(12).collect(),
                wanted: "i8".into(),
            }),
        }
    }

    pub fn i32(&self, name: &str) -> Result<(&[usize], &[i32]), BinError> {
        match self.get(name)? {
            Tensor::I32 { shape, data } => Ok((shape, data)),
            t => Err(BinError::Dtype {
                name: name.into(),
                actual: format!("{t:?}").chars().take(12).collect(),
                wanted: "i32".into(),
            }),
        }
    }

    /// All tensor names with a given prefix, in lexicographic order.
    pub fn names_with_prefix(&self, prefix: &str) -> Vec<&str> {
        self.tensors
            .keys()
            .filter(|k| k.starts_with(prefix))
            .map(|s| s.as_str())
            .collect()
    }
}

fn dtype_size(d: &str) -> usize {
    match d {
        "i8" => 1,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_fixture(dir: &Path) {
        // mirror io_bin.py's layout by hand
        let f32s: Vec<u8> = [1.0f32, -2.5, 3.25]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let i8s: Vec<u8> = vec![0xFFu8, 0, 1]; // -1, 0, 1
        let i32s: Vec<u8> = [7i32, -9].iter().flat_map(|v| v.to_le_bytes()).collect();
        let mut bin = Vec::new();
        bin.extend(&f32s);
        bin.extend(&i8s);
        bin.extend(&i32s);
        std::fs::File::create(dir.join("t.bin"))
            .unwrap()
            .write_all(&bin)
            .unwrap();
        let manifest = format!(
            r#"{{"meta": {{"k": 2}}, "tensors": [
              {{"name": "a", "dtype": "f32", "shape": [3], "offset": 0, "nbytes": 12}},
              {{"name": "b", "dtype": "i8", "shape": [3], "offset": 12, "nbytes": 3}},
              {{"name": "c", "dtype": "i32", "shape": [2], "offset": 15, "nbytes": 8}}
            ]}}"#
        );
        std::fs::write(dir.join("t.json"), manifest).unwrap();
    }

    #[test]
    fn decodes_all_dtypes() {
        let dir = std::env::temp_dir().join("memdyn_binio_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_fixture(&dir);
        let b = Bundle::load(&dir.join("t")).unwrap();
        assert_eq!(b.meta.get("k").unwrap().as_usize(), Some(2));
        let (shape, a) = b.f32("a").unwrap();
        assert_eq!(shape, &[3]);
        assert_eq!(a, vec![1.0, -2.5, 3.25]);
        let (_, i8s) = b.i8("b").unwrap();
        assert_eq!(i8s, &[-1, 0, 1]);
        let (_, i32s) = b.i32("c").unwrap();
        assert_eq!(i32s, &[7, -9]);
    }

    #[test]
    fn missing_tensor_is_error() {
        let dir = std::env::temp_dir().join("memdyn_binio_test2");
        std::fs::create_dir_all(&dir).unwrap();
        write_fixture(&dir);
        let b = Bundle::load(&dir.join("t")).unwrap();
        assert!(matches!(b.get("zzz"), Err(BinError::NotFound(_))));
        assert!(b.i8("a").is_err()); // dtype mismatch
    }

    #[test]
    fn prefix_listing_sorted() {
        let dir = std::env::temp_dir().join("memdyn_binio_test3");
        std::fs::create_dir_all(&dir).unwrap();
        write_fixture(&dir);
        let b = Bundle::load(&dir.join("t")).unwrap();
        assert_eq!(b.names_with_prefix("a"), vec!["a"]);
        assert_eq!(b.names_with_prefix("").len(), 3);
    }
}
