//! Persistent std-only worker pool (long-lived threads fed over `mpsc`).
//!
//! The vendored crate set has no rayon; everything the simulator needs is
//! "split this index range / item list across N cores and join".  Results
//! come back in input order, so callers stay deterministic as long as the
//! work items themselves are (which the [`StreamKey`] noise streams
//! guarantee — see `util::rng`).
//!
//! Workers are **pooled, not spawned per call**: the first dispatching
//! call lazily spawns long-lived worker threads (capped by
//! `MEMDYN_THREADS`, else the machine's available parallelism) that block
//! on a shared `mpsc` job queue.  Per-call `thread::scope` spawn+join was
//! fine for analogue spans (hundreds of µs to seconds of MVM work per
//! chunk) but its ~10 µs per-thread cost dominates small digital batches
//! on the serving path; with the pool a dispatch is one channel send.
//! [`run_chunks_scoped`] keeps the old fork-join implementation as the
//! reference the `spawn_overhead` bench and the property tests compare
//! against.
//!
//! Rules of the pool:
//!
//! * **The caller works too.** `run_chunks` hands chunks `1..` to the
//!   pool and runs chunk `0` on the calling thread, so a width-`t` call
//!   occupies the caller plus `t - 1` workers.
//! * **Nested calls run inline.** A pool call made *from inside a pool
//!   worker* executes sequentially on that worker (no re-dispatch).
//!   Workers therefore never block on the queue they drain, which rules
//!   out exhaustion deadlock by construction; results are unchanged
//!   because chunking never affects values, only scheduling.
//! * **No idle lane, no dispatch.** A call that finds no free lane
//!   (every worker accounted for by queued-or-running tasks, or none
//!   spawnable) runs inline rather than parking its chunks behind
//!   unrelated jobs on the FIFO queue — head-of-line blocking would
//!   make small fan-outs slower than serial.  Scheduling-only, like
//!   the nesting rule.
//! * **Panics propagate.** A panicking chunk is caught on the worker,
//!   shipped back, and re-raised on the caller *after* every sibling
//!   chunk has finished — no borrow held by a job can outlive the call.
//! * **Shutdown is explicit and optional.** [`restart`] drains and joins
//!   the workers (never call it from inside a pool task); the next
//!   dispatching call re-spawns lazily.  Exiting the process with idle
//!   workers parked on the queue is fine.
//!
//! [`StreamKey`]: crate::util::rng::StreamKey

use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

/// Process-local override for [`max_threads`] (0 = none).  Mutating
/// `MEMDYN_THREADS` itself via `env::set_var` races with concurrent
/// `env::var` readers (libc getenv/setenv are not thread-safe), so
/// multi-threaded test binaries and the bench sweeps pin the width here
/// instead.
static THREADS_OVERRIDE: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// Pin [`max_threads`] — and with it every default fan-out width and the
/// pool's worker cap — standing in for `MEMDYN_THREADS` where touching
/// the process environment would be racy.  0 restores the default.
/// Usually paired with [`restart`] so the worker set re-grows under the
/// new cap.
pub fn set_max_threads(threads: usize) {
    THREADS_OVERRIDE.store(threads, std::sync::atomic::Ordering::Relaxed);
}

/// Worker count for parallel sections: the [`set_max_threads`] override
/// if set, else `MEMDYN_THREADS`, else the machine's available
/// parallelism, else 1.
pub fn max_threads() -> usize {
    match THREADS_OVERRIDE.load(std::sync::atomic::Ordering::Relaxed) {
        0 => {}
        n => return n,
    }
    if let Ok(v) = std::env::var("MEMDYN_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Absolute ceiling on pool size, guarding against absurd width requests.
const MAX_WORKERS: usize = 256;

/// The pool's worker-count cap (re-read on every spawn decision so a
/// [`restart`] picks up a new `MEMDYN_THREADS`/[`set_max_threads`] cap).
fn worker_cap() -> usize {
    max_threads().min(MAX_WORKERS)
}

/// A type-erased unit of work (lifetime erased by `erase_task`).
type Task = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    tx: Sender<Task>,
    rx: Arc<Mutex<Receiver<Task>>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// Tasks submitted and not yet finished — queued *or* running, so a
/// backlogged queue reads as "no idle lane".  Each task decrements the
/// counter itself, *before* shipping its result: a caller that has
/// collected all its results therefore observes a drained counter, and
/// back-to-back dispatches (consecutive kernels, the server's batch
/// loop) never see a stale "busy" reading for work that already
/// completed.  Dispatchers use this to avoid parking chunks behind
/// unrelated jobs on the FIFO queue (head-of-line blocking), which
/// would make small fan-outs slower than serial.
static OUTSTANDING_TASKS: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

impl PoolState {
    fn new() -> Self {
        let (tx, rx) = channel::<Task>();
        PoolState {
            tx,
            rx: Arc::new(Mutex::new(rx)),
            workers: Vec::new(),
        }
    }

    /// Lazily spawn workers until `want` are live (clamped to the cap).
    /// Best-effort: a spawn failure (thread limit) leaves the pool at
    /// its current size instead of panicking — dispatch works at any
    /// worker count, including zero (see `submit`).  Panicking here
    /// would unwind a `run_chunks` caller while lifetime-erased tasks
    /// still borrow its stack, which must never happen.
    fn ensure_workers(&mut self, want: usize) {
        let want = want.min(worker_cap());
        while self.workers.len() < want {
            let rx = Arc::clone(&self.rx);
            let idx = self.workers.len();
            match std::thread::Builder::new()
                .name(format!("memdyn-pool-{idx}"))
                .spawn(move || worker_loop(rx))
            {
                Ok(handle) => self.workers.push(handle),
                Err(_) => break,
            }
        }
    }
}

static POOL: Mutex<Option<PoolState>> = Mutex::new(None);

thread_local! {
    static IN_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

fn in_worker() -> bool {
    IN_WORKER.with(|w| w.get())
}

fn worker_loop(rx: Arc<Mutex<Receiver<Task>>>) {
    IN_WORKER.with(|w| w.set(true));
    loop {
        // hold the lock only for the blocking recv; run the task after
        // the guard is dropped so siblings can pick up the next job
        let task = {
            let guard = rx.lock().unwrap_or_else(|e| e.into_inner());
            guard.recv()
        };
        match task {
            Ok(task) => task(), // the task body maintains OUTSTANDING_TASKS
            Err(_) => return,   // queue drained and pool shut down
        }
    }
}

/// Erase the lifetime of a boxed task.
///
/// # Safety
///
/// The caller must not return (or unwind) until the task has either run
/// to completion or been destroyed unrun — `run_chunks` guarantees this
/// by draining one result message per submitted job before returning.
unsafe fn erase_task<'a>(task: Box<dyn FnOnce() + Send + 'a>) -> Task {
    std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Task>(task)
}

/// Grow the pool toward `want` workers and report whether dispatching
/// is worthwhile right now: returns a sender only when at least one
/// worker exists *and* at least one lane is idle.  With every worker
/// busy, queued chunks would sit behind unrelated jobs while the caller
/// blocks — running inline is strictly better.
fn acquire_lanes(want: usize) -> Option<Sender<Task>> {
    let mut guard = POOL.lock().unwrap_or_else(|e| e.into_inner());
    let state = guard.get_or_insert_with(PoolState::new);
    state.ensure_workers(want);
    let alive = state.workers.len();
    let outstanding = OUTSTANDING_TASKS.load(std::sync::atomic::Ordering::Relaxed);
    if alive == 0 || outstanding >= alive {
        None
    } else {
        Some(state.tx.clone())
    }
}

/// Submit a task on a sender obtained from `acquire_lanes`.  If the
/// pool was shut down in between, the task runs inline on the caller.
fn submit(tx: &Sender<Task>, task: Task) {
    if let Err(returned) = tx.send(task) {
        (returned.0)();
    }
}

/// Pre-spawn workers for a width-`threads` caller (e.g. at server start),
/// so the first request does not pay the lazy spawn.  No-op at width 1.
pub fn prewarm(threads: usize) {
    if threads <= 1 {
        return;
    }
    let mut guard = POOL.lock().unwrap_or_else(|e| e.into_inner());
    guard
        .get_or_insert_with(PoolState::new)
        .ensure_workers(threads - 1);
}

/// Live worker-thread count (0 before the first dispatch or after
/// [`restart`]).  Observability for tests and the bench harness.
pub fn workers_alive() -> usize {
    POOL.lock()
        .unwrap_or_else(|e| e.into_inner())
        .as_ref()
        .map_or(0, |s| s.workers.len())
}

/// Shut the pool down: close the queue, let workers drain any queued
/// jobs, and join them.  The next dispatching call re-spawns lazily, so
/// this is a *restart* from the caller's point of view.  Must not be
/// called from inside a pool task (a worker cannot join itself).
pub fn restart() {
    let state = POOL.lock().unwrap_or_else(|e| e.into_inner()).take();
    if let Some(state) = state {
        drop(state.tx);
        drop(state.rx);
        for handle in state.workers {
            let _ = handle.join();
        }
    }
}

/// Split `0..n` into at most `threads` contiguous chunks of near-equal
/// size (first chunks one larger when `n % threads != 0`).
pub fn chunk_ranges(n: usize, threads: usize) -> Vec<Range<usize>> {
    let t = threads.max(1).min(n.max(1));
    let base = n / t;
    let extra = n % t;
    let mut out = Vec::with_capacity(t);
    let mut at = 0;
    for i in 0..t {
        let len = base + usize::from(i < extra);
        out.push(at..at + len);
        at += len;
    }
    debug_assert_eq!(at, n);
    out
}

/// Run `f` over the chunks of `0..n` on up to `threads` lanes of the
/// persistent pool; returns per-chunk results in chunk order.  The caller
/// runs chunk 0 itself; `threads <= 1` (or a single chunk, or a call from
/// inside a pool worker) runs fully inline on the caller's thread.
pub fn run_chunks<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    let mut ranges = chunk_ranges(n, threads);
    if ranges.len() <= 1 || in_worker() {
        return ranges.into_iter().map(&f).collect();
    }
    let n_rest = ranges.len() - 1;
    let Some(pool_tx) = acquire_lanes(n_rest) else {
        // no idle lane (or no spawnable worker): inline beats queueing
        // behind unrelated jobs
        return ranges.into_iter().map(&f).collect();
    };
    let first = ranges.remove(0);
    let (rtx, rrx) = channel::<(usize, std::thread::Result<T>)>();
    for (i, r) in ranges.into_iter().enumerate() {
        let tx = rtx.clone();
        let fref = &f;
        let task: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
            let res = catch_unwind(AssertUnwindSafe(|| fref(r)));
            // drain the lane accounting before delivering the result, so
            // a dispatcher that has seen every result also sees the
            // counter at rest (no stale-busy window)
            OUTSTANDING_TASKS.fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
            let _ = tx.send((i, res));
        });
        OUTSTANDING_TASKS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // SAFETY: the task borrows `f` and carries a non-'static `T`.
        // Nothing on this path panics before the drain loop below, which
        // collects one result message per job before returning or
        // unwinding; a queue disconnect (the only early exit) proves
        // every job closure — and thus every borrow — is already gone.
        let task = unsafe { erase_task(task) };
        submit(&pool_tx, task);
    }
    drop(rtx);
    // the caller thread takes the first chunk instead of blocking idle
    let r0 = catch_unwind(AssertUnwindSafe(|| f(first)));
    let mut rest: Vec<Option<std::thread::Result<T>>> = Vec::with_capacity(n_rest);
    rest.resize_with(n_rest, || None);
    let mut received = 0usize;
    while received < n_rest {
        match rrx.recv() {
            Ok((i, res)) => {
                rest[i] = Some(res);
                received += 1;
            }
            Err(_) => break, // every job ran or was destroyed unrun
        }
    }
    let mut out = Vec::with_capacity(n_rest + 1);
    match r0 {
        Ok(v) => out.push(v),
        Err(payload) => resume_unwind(payload),
    }
    for (i, slot) in rest.into_iter().enumerate() {
        match slot {
            Some(Ok(v)) => out.push(v),
            Some(Err(payload)) => resume_unwind(payload),
            None => panic!("pool dropped chunk {} (shut down mid-call)", i + 1),
        }
    }
    out
}

/// Map `f` over `0..n` items on up to `threads` pool lanes; returns the
/// per-item results in item order.
pub fn map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let per_chunk = run_chunks(n, threads, |r| r.map(&f).collect::<Vec<T>>());
    per_chunk.into_iter().flatten().collect()
}

/// Run `f` over the chunks of `0..n` and concatenate the per-chunk Vecs
/// in chunk order — the "rows of a fixed-width output" pattern shared by
/// the keyed crossbar matmul and the interpreter's `dot`/`convolution`
/// fan-outs.  A single chunk is returned without copying.
pub fn run_chunks_flat<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> Vec<T> + Sync,
{
    let mut parts = run_chunks(n, threads, f);
    if parts.len() == 1 {
        return parts.pop().unwrap();
    }
    let total = parts.iter().map(Vec::len).sum();
    let mut out = Vec::with_capacity(total);
    for p in parts {
        out.extend(p);
    }
    out
}

/// The pre-pool implementation: run `f` over the chunks of `0..n` on
/// per-call `std::thread::scope` threads.  Kept as the dispatch-cost
/// reference for the `spawn_overhead` bench rows and as the independent
/// oracle the pool property tests compare against; production call sites
/// use [`run_chunks`].
pub fn run_chunks_scoped<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    let ranges = chunk_ranges(n, threads);
    if ranges.len() <= 1 {
        return ranges.into_iter().map(&f).collect();
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = ranges.into_iter().map(|r| s.spawn(|| f(r))).collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("scoped worker panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_range_in_order() {
        for (n, t) in [(10, 3), (7, 7), (3, 8), (0, 4), (16, 1)] {
            let rs = chunk_ranges(n, t);
            let mut at = 0;
            for r in &rs {
                assert_eq!(r.start, at);
                at = r.end;
            }
            assert_eq!(at, n);
            assert!(rs.len() <= t.max(1));
        }
    }

    #[test]
    fn run_chunks_preserves_order() {
        let got = run_chunks(100, 4, |r| r.sum::<usize>());
        assert_eq!(got.iter().sum::<usize>(), (0..100).sum::<usize>());
        assert_eq!(got.len(), 4);
    }

    #[test]
    fn map_matches_sequential() {
        for threads in [1, 2, 8] {
            let got = map(50, threads, |i| i * i);
            let want: Vec<usize> = (0..50).map(|i| i * i).collect();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn single_thread_runs_inline() {
        // must not deadlock or reorder with threads == 1
        let got = map(5, 1, |i| i + 1);
        assert_eq!(got, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn pooled_matches_scoped_reference() {
        for (n, t) in [(0usize, 3usize), (1, 4), (9, 2), (64, 8), (5, 9)] {
            let pooled = run_chunks(n, t, |r| r.map(|i| i * 7 + 1).sum::<usize>());
            let scoped = run_chunks_scoped(n, t, |r| r.map(|i| i * 7 + 1).sum::<usize>());
            assert_eq!(pooled, scoped, "n={n} t={t}");
        }
    }

    #[test]
    fn nested_call_from_worker_runs_inline() {
        // inner pool call inside a pool job must complete (no deadlock)
        // and agree with the flat computation
        let inner_sum: usize = (0..16).map(|i| i + 1).sum();
        let got = run_chunks(8, 4, |outer| {
            let inner: usize = map(16, 4, |i| i + 1).into_iter().sum();
            outer.sum::<usize>() + inner
        });
        let want: Vec<usize> = chunk_ranges(8, 4)
            .into_iter()
            .map(|r| r.sum::<usize>() + inner_sum)
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn worker_panic_propagates_to_caller() {
        let _ = run_chunks(4, 4, |r| {
            if r.start == 2 {
                panic!("boom");
            }
            r.len()
        });
    }

    #[test]
    fn pool_is_capped_and_survives_restart() {
        let before = map(40, 4, |i| i * 3);
        assert!(workers_alive() <= worker_cap());
        restart();
        let after = map(40, 4, |i| i * 3);
        assert_eq!(before, after);
        assert!(workers_alive() <= worker_cap());
    }
}
