//! Minimal std-only fork-join helpers (`std::thread::scope`).
//!
//! The vendored crate set has no rayon; everything the simulator needs is
//! "split this index range / item list across N cores and join".  Results
//! come back in input order, so callers stay deterministic as long as the
//! work items themselves are (which the [`StreamKey`] noise streams
//! guarantee — see `util::rng`).
//!
//! Threads are spawned per call, not pooled: the analogue spans these
//! helpers fan out (hundreds of µs to seconds of MVM work) dwarf the
//! ~10 µs spawn+join cost.  For very small digital batches the serving
//! path should prefer `--threads 1`; a persistent worker pool is a
//! recorded follow-up (ROADMAP) to be justified by the EXPERIMENTS.md
//! serving p99 numbers, not assumed.
//!
//! [`StreamKey`]: crate::util::rng::StreamKey

use std::ops::Range;

/// Worker count for parallel sections: `MEMDYN_THREADS` if set, else the
/// machine's available parallelism, else 1.
pub fn max_threads() -> usize {
    if let Ok(v) = std::env::var("MEMDYN_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Split `0..n` into at most `threads` contiguous chunks of near-equal
/// size (first chunks one larger when `n % threads != 0`).
pub fn chunk_ranges(n: usize, threads: usize) -> Vec<Range<usize>> {
    let t = threads.max(1).min(n.max(1));
    let base = n / t;
    let extra = n % t;
    let mut out = Vec::with_capacity(t);
    let mut at = 0;
    for i in 0..t {
        let len = base + usize::from(i < extra);
        out.push(at..at + len);
        at += len;
    }
    debug_assert_eq!(at, n);
    out
}

/// Run `f` over the chunks of `0..n` on up to `threads` scoped threads;
/// returns per-chunk results in chunk order.  `threads <= 1` (or a single
/// chunk) runs inline on the caller's thread.
pub fn run_chunks<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    let ranges = chunk_ranges(n, threads);
    if ranges.len() <= 1 {
        return ranges.into_iter().map(&f).collect();
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = ranges
            .into_iter()
            .map(|r| s.spawn(|| f(r)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("pool worker panicked"))
            .collect()
    })
}

/// Map `f` over `0..n` items on up to `threads` scoped threads; returns
/// the per-item results in item order.
pub fn map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let per_chunk = run_chunks(n, threads, |r| r.map(&f).collect::<Vec<T>>());
    per_chunk.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_range_in_order() {
        for (n, t) in [(10, 3), (7, 7), (3, 8), (0, 4), (16, 1)] {
            let rs = chunk_ranges(n, t);
            let mut at = 0;
            for r in &rs {
                assert_eq!(r.start, at);
                at = r.end;
            }
            assert_eq!(at, n);
            assert!(rs.len() <= t.max(1));
        }
    }

    #[test]
    fn run_chunks_preserves_order() {
        let got = run_chunks(100, 4, |r| r.sum::<usize>());
        assert_eq!(got.iter().sum::<usize>(), (0..100).sum::<usize>());
        assert_eq!(got.len(), 4);
    }

    #[test]
    fn map_matches_sequential() {
        for threads in [1, 2, 8] {
            let got = map(50, threads, |i| i * i);
            let want: Vec<usize> = (0..50).map(|i| i * i).collect();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn single_thread_runs_inline() {
        // must not deadlock or reorder with threads == 1
        let got = map(5, 1, |i| i + 1);
        assert_eq!(got, vec![1, 2, 3, 4, 5]);
    }
}
