//! Fig. 6 bench: TPE optimizer — iterations/second on a real calibration
//! trace, plus the TPE vs random-search vs coordinate-descent quality
//! comparison at equal evaluation budgets.

use memdyn::budget::BudgetModel;
use memdyn::figures::common::{self as common, Setup, Variant};
use memdyn::model::artifacts_dir;
use memdyn::opt::{self, Objective};
use memdyn::util::bench::standard_bencher;

fn main() {
    let dir = artifacts_dir(None);
    if !dir.join("index.json").exists() {
        println!("SKIP fig6 bench: no artifacts");
        return;
    }
    let b = standard_bencher("fig6: TPE threshold optimization");
    let setup = Setup::new(&dir, 100);
    let (bundle, data) = setup.resnet().unwrap();
    let budget = BudgetModel::new(
        bundle.block_ops.clone(),
        &bundle.exit_dims,
        bundle.classes,
    );
    let engine = common::resnet_engine(&bundle, Variant::EeQun, 11).unwrap();
    let trace = common::trace_train(&engine, &data, 400, 25).unwrap();
    let objective = Objective::default();

    println!(
        "{}",
        b.run_items("tpe_200_iters (evals/s)", 200.0, || {
            opt::tpe::optimize(
                &trace,
                &budget,
                &objective,
                &opt::tpe::TpeConfig {
                    n_iters: 200,
                    ..Default::default()
                },
            )
            .best
            .score
        })
        .report()
    );

    // quality at equal budget
    for iters in [100usize, 400, 1000] {
        let tpe = opt::tpe::optimize(
            &trace,
            &budget,
            &objective,
            &opt::tpe::TpeConfig {
                n_iters: iters,
                ..Default::default()
            },
        );
        let rnd = opt::random::search(&trace, &budget, &objective, 0.3, 1.05, iters, 7);
        println!(
            "iters {iters:>4}: TPE score {:.4} (acc {:.1}%, budget {:.1}%) | random {:.4}",
            tpe.best.score,
            tpe.best.accuracy * 100.0,
            tpe.best.budget_drop * 100.0,
            rnd.best.score
        );
    }
    let start = vec![0.9f32; trace.n_exits];
    let cd = opt::grid::coordinate_descent(
        &trace,
        &budget,
        &objective,
        &start,
        0.3,
        1.05,
        16,
        3,
    );
    println!("coordinate-descent baseline: score {:.4}", cd.score);

    for fig in ["6a", "6hk"] {
        let t0 = std::time::Instant::now();
        match memdyn::figures::run(fig, &setup) {
            Ok(text) => {
                println!("{text}");
                println!("[fig {fig}: {:.1}s]\n", t0.elapsed().as_secs_f64());
            }
            Err(e) => println!("[fig {fig} FAILED: {e:#}]"),
        }
    }
}
