//! Fig. 3 bench: regenerates the ResNet/MNIST ablation (3e), OPs/layer
//! (3g) and energy comparison (3h) end-to-end, and times the trace
//! recording that every row depends on.  Skips cleanly without artifacts.

use memdyn::budget::BudgetModel;
use memdyn::figures::common::{self as common, Setup, Variant};
use memdyn::model::artifacts_dir;
use memdyn::util::bench::standard_bencher;

fn main() {
    let dir = artifacts_dir(None);
    if !dir.join("index.json").exists() {
        println!("SKIP fig3 bench: no artifacts (run `make artifacts`)");
        return;
    }
    let b = standard_bencher("fig3: dynamic ResNet on synthetic MNIST");
    let samples = std::env::var("MEMDYN_BENCH_SAMPLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(150);
    let setup = Setup::new(&dir, samples);

    // time the per-sample early-exit inference on the digital backend
    let (bundle, data) = setup.resnet().unwrap();
    let budget = BudgetModel::new(
        bundle.block_ops.clone(),
        &bundle.exit_dims,
        bundle.classes,
    );
    let engine = common::resnet_engine(&bundle, Variant::EeQun, 11).unwrap();
    let calib = common::trace_train(&engine, &data, 300, 25).unwrap();
    let thr = common::tuned_thresholds(&bundle, &calib, &budget, 200).unwrap();
    let mut engine = engine;
    engine.thresholds = thr.values.clone();
    let n = 50usize;
    let input = &data.x_test[..n * data.sample_len];
    let quick = memdyn::util::bench::Bencher::new(1, 3);
    println!(
        "{}",
        quick
            .run_items("ee_infer_digital_50 (samples/s)", n as f64, || {
                engine.infer_batch(input, n).unwrap().len()
            })
            .report()
    );
    let _ = b;

    // native-vs-interpreter latency on the digital path: the same 50
    // samples through the XLA backend (AOT HLO artifacts on the native
    // interpreter, bucket-padded batching).  Compare against the
    // ee_infer_digital_50 row above — this is the EXPERIMENTS.md §Perf
    // "digital path: native vs interpreter" pair.
    {
        let rt = memdyn::runtime::Runtime::cpu().unwrap();
        let xla =
            memdyn::coordinator::dynmodel::XlaResNetModel::load(&rt, &bundle).unwrap();
        let memory = memdyn::coordinator::ExitMemory::build(
            &bundle,
            memdyn::coordinator::CenterSource::TernaryQ,
            &memdyn::nn::NoiseSpec::Digital,
            7,
        )
        .unwrap();
        let xla_engine =
            memdyn::coordinator::Engine::new(xla, memory, thr.values.clone());
        println!(
            "{}",
            quick
                .run_items("ee_infer_xla_interp_50 (samples/s)", n as f64, || {
                    xla_engine.infer_batch(input, n).unwrap().len()
                })
                .report()
        );

        // the same batch with the interpreter's dot/convolution row
        // fan-out pinned to 1 and 4 (outputs bit-identical; only the
        // wall clock moves).  This is the §Perf "in-place loop buffers +
        // row-parallel kernels" series.  The model's chunk-level fan-out
        // is capped to 1 so every kernel runs on the caller, where the
        // row fan-out knob actually applies (inside pool workers nested
        // calls run inline and the knob would be inert).
        let xla1 = memdyn::coordinator::dynmodel::XlaResNetModel::load(&rt, &bundle)
            .unwrap()
            .with_threads(1);
        let memory1 = memdyn::coordinator::ExitMemory::build(
            &bundle,
            memdyn::coordinator::CenterSource::TernaryQ,
            &memdyn::nn::NoiseSpec::Digital,
            7,
        )
        .unwrap();
        let lin_engine =
            memdyn::coordinator::Engine::new(xla1, memory1, thr.values.clone());
        for fanout in [1usize, 4] {
            memdyn::hlo::eval::set_linear_fanout(fanout);
            println!(
                "{}",
                quick
                    .run_items(
                        &format!("ee_infer_xla_interp_50_lin{fanout} (samples/s)"),
                        n as f64,
                        || lin_engine.infer_batch(input, n).unwrap().len()
                    )
                    .report()
            );
        }
        // ablation: the same lin4 run with the bit-packed ternary dot
        // kernel switched off, so every ternary-constant dot falls back
        // to the dense f32 loop.  The lin4-vs-lin4_dense pair is the
        // §Perf "packed ternary dot" before/after on the serving graph.
        memdyn::hlo::eval::set_linear_fanout(4);
        memdyn::cim::packed::set_enabled(false);
        println!(
            "{}",
            quick
                .run_items(
                    "ee_infer_xla_interp_50_lin4_dense (samples/s)",
                    n as f64,
                    || lin_engine.infer_batch(input, n).unwrap().len()
                )
                .report()
        );
        memdyn::cim::packed::set_enabled(true);
        memdyn::hlo::eval::set_linear_fanout(0);
        println!(
            "[dynamic-update-slice: {} in-place, {} copied so far this process]",
            memdyn::hlo::eval::dus_in_place_count(),
            memdyn::hlo::eval::dus_copied_count()
        );
        println!(
            "[dot dispatch: {} packed, {} dense so far this process]",
            memdyn::hlo::eval::dot_packed_count(),
            memdyn::hlo::eval::dot_dense_count()
        );
    }

    // Mem-variant wall clock vs thread count: the paper's noise-robust
    // ternary macro simulation, full depth (placeholder thresholds never
    // exit early), bit-identical outputs at every width.  This is the
    // EXPERIMENTS.md "parallel crossbar simulation" headline series.
    let nm = 24usize.min(data.n_test());
    let mem_input = &data.x_test[..nm * data.sample_len];
    for threads in [1usize, 2, 4] {
        let mem_engine = common::resnet_engine(&bundle, Variant::Mem, 33)
            .unwrap()
            .with_threads(threads);
        let name = format!("mem_infer_{nm}_t{threads} (samples/s)");
        println!(
            "{}",
            quick
                .run_items(&name, nm as f64, || {
                    mem_engine.infer_batch(mem_input, nm).unwrap().len()
                })
                .report()
        );
    }

    // the actual figure regenerations
    for fig in ["3e", "3g", "3h"] {
        let t0 = std::time::Instant::now();
        match memdyn::figures::run(fig, &setup) {
            Ok(text) => {
                println!("{text}");
                println!("[fig {fig}: {:.1}s]\n", t0.elapsed().as_secs_f64());
            }
            Err(e) => println!("[fig {fig} FAILED: {e:#}]"),
        }
    }
}
