//! Fig. 5 bench: dynamic PointNet++ ablation (5e), OPs/pass-through (5g)
//! and energy (5h), plus FPS/ball-query substrate timings.

use memdyn::figures::common::Setup;
use memdyn::model::artifacts_dir;
use memdyn::nn::pointnet::{ball_query, farthest_point_sample};
use memdyn::util::bench::standard_bencher;
use memdyn::util::rng::Pcg64;

fn main() {
    let b = standard_bencher("fig5: dynamic PointNet++ on synthetic ModelNet");
    let mut rng = Pcg64::new(4);
    let n = 256usize;
    let xyz: Vec<f32> = (0..n * 3)
        .map(|_| rng.uniform_in(-1.0, 1.0) as f32)
        .collect();
    println!(
        "{}",
        b.run("fps_256->128", || farthest_point_sample(&xyz, n, 128).len())
            .report()
    );
    let centers = farthest_point_sample(&xyz, n, 128);
    println!(
        "{}",
        b.run("ball_query_128x256_k16", || {
            ball_query(&xyz, n, &centers, 0.3, 16).len()
        })
        .report()
    );

    let dir = artifacts_dir(None);
    if !dir.join("index.json").exists() {
        println!("SKIP fig5 figures: no artifacts");
        return;
    }
    let samples = std::env::var("MEMDYN_BENCH_SAMPLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60);
    let setup = Setup::new(&dir, samples);
    for fig in ["5e", "5g", "5h"] {
        let t0 = std::time::Instant::now();
        match memdyn::figures::run(fig, &setup) {
            Ok(text) => {
                println!("{text}");
                println!("[fig {fig}: {:.1}s]\n", t0.elapsed().as_secs_f64());
            }
            Err(e) => println!("[fig {fig} FAILED: {e:#}]"),
        }
    }
}
