//! §Perf hot-path micro-benches: the numbers tracked before/after each
//! optimization in EXPERIMENTS.md §Perf.
//!
//! Covers the L3 request path end to end: crossbar MVM (the Mem backend's
//! inner loop), the pooled keyed batch MVM, pool-vs-scoped dispatch
//! overhead (`spawn_overhead` rows), the sharded server at replicas
//! 1/2/4 (`serve_toy_r{1,2,4}` rows) and with observability on vs off
//! (`serve_toy_obs_{on,off}` rows), im2col, GroupNorm, the dense
//! digital matmul, the HLO interpreter's compiled step program vs the
//! tree walk (`hlo_while_dus_192_{planned,tree}` rows), and CAM search.

use std::time::Duration;

use memdyn::cim::packed::PackedTernary;
use memdyn::cim::CimMatrix;
use memdyn::coordinator::dynmodel::DynModel;
use memdyn::coordinator::{Engine, ExitMemory, Server, ServerConfig};
use memdyn::crossbar::ConverterConfig;
use memdyn::device::DeviceConfig;
use memdyn::nn::ops;
use memdyn::util::bench::standard_bencher;
use memdyn::util::pool;
use memdyn::util::rng::{Pcg64, StreamKey};

/// Artifact-free toy backbone for the serving-path shard sweep: enough
/// arithmetic per block (a 64x64 dense layer) that batches cost real
/// work, but cheap enough that the *dispatch* machinery — admission
/// queue, batch assembly, replica fan-out — stays visible.
struct BenchToy {
    w: Vec<f32>,
}

const BT_DIM: usize = 64;
const BT_BLOCKS: usize = 2;

impl DynModel for BenchToy {
    type State = Vec<Vec<f32>>;

    fn n_blocks(&self) -> usize {
        BT_BLOCKS
    }

    fn classes(&self) -> usize {
        2
    }

    fn input_len(&self) -> Option<usize> {
        Some(BT_DIM)
    }

    fn init(&self, input: &[f32], batch: usize, _reqs: &[u64]) -> anyhow::Result<Self::State> {
        Ok((0..batch)
            .map(|i| input[i * BT_DIM..(i + 1) * BT_DIM].to_vec())
            .collect())
    }

    fn step(&self, _i: usize, state: &mut Self::State) -> anyhow::Result<Vec<f32>> {
        for row in state.iter_mut() {
            let y: Vec<f32> = (0..BT_DIM)
                .map(|o| {
                    let mut acc = 0f32;
                    for (k, v) in row.iter().enumerate() {
                        acc += v * self.w[k * BT_DIM + o];
                    }
                    (acc / BT_DIM as f32).tanh()
                })
                .collect();
            *row = y;
        }
        Ok(state.concat())
    }

    fn batch_of(&self, state: &Self::State) -> usize {
        state.len()
    }

    fn select(&self, state: &Self::State, keep: &[usize]) -> Self::State {
        keep.iter().map(|&r| state[r].clone()).collect()
    }

    fn finish(&self, state: &Self::State) -> anyhow::Result<Vec<f32>> {
        Ok(state.iter().flat_map(|r| r[..2].to_vec()).collect())
    }
}

fn bench_toy_engine() -> Engine<BenchToy> {
    let mut rng = Pcg64::new(42);
    let w: Vec<f32> = (0..BT_DIM * BT_DIM)
        .map(|_| rng.uniform_in(-1.0, 1.0) as f32)
        .collect();
    // centers the toy inputs never match: every request runs full depth,
    // so the served work per request is fixed across replica counts
    let bank = (vec![1.0f32; BT_DIM * 2], 2usize, BT_DIM);
    Engine::new(
        BenchToy { w },
        ExitMemory::exact(vec![bank.clone(); BT_BLOCKS]),
        vec![2.0; BT_BLOCKS],
    )
}

fn main() {
    let b = standard_bencher("hotpath micro-benches");
    let mut rng = Pcg64::new(1);

    // --- crossbar MVM: 512x256 tile, the Mem backend's inner loop --------
    let (k, n) = (512usize, 256usize);
    let w: Vec<i8> = (0..k * n).map(|_| [-1i8, 0, 1][rng.below(3)]).collect();
    let noisy = CimMatrix::program(
        &w,
        k,
        n,
        &DeviceConfig::default(),
        &ConverterConfig::default(),
        &mut rng,
    );
    let ideal = CimMatrix::program(
        &w,
        k,
        n,
        &DeviceConfig::ideal(),
        &ConverterConfig::ideal(),
        &mut rng,
    );
    let x: Vec<f32> = (0..k).map(|i| (i as f32 * 0.7).sin()).collect();
    let mut y = vec![0f32; n];
    let mut rng2 = Pcg64::new(2);
    let reads = (k * 2 * n) as f64;
    println!(
        "{}",
        b.run_items("xbar_mvm_512x256_noisy (device reads/s)", reads, || {
            noisy.mvm(&x, &mut y, &mut rng2);
            y[0]
        })
        .report()
    );
    println!(
        "{}",
        b.run_items("xbar_mvm_512x256_ideal (device reads/s)", reads, || {
            ideal.mvm(&x, &mut y, &mut rng2);
            y[0]
        })
        .report()
    );

    // --- multi-core keyed batch MVM: the parallel Mem engine's fan-out ----
    // a 32-sample batch over the noisy tile at pool widths 1/2/4/8 with
    // per-request noise streams (outputs identical at every width); this
    // is the §Perf "per-tile RNG streams" before/after series.  The width
    // is pinned via pool::set_max_threads (the race-free stand-in for
    // MEMDYN_THREADS) and the pool is restarted under each cap.
    let batch = 32usize;
    let xb: Vec<f32> = (0..batch * k)
        .map(|i| ((i % 23) as f32 - 11.0) / 11.0)
        .collect();
    let root = StreamKey::root(9);
    let keys: Vec<StreamKey> = (0..batch as u64).map(|i| root.child(i)).collect();
    for threads in [1usize, 2, 4, 8] {
        // pin the pool width the race-free way (no env mutation), and
        // restart so the worker set re-grows under the new cap
        pool::set_max_threads(threads);
        pool::restart();
        let name = format!("xbar_matmul_b32_noisy_t{threads} (device reads/s)");
        println!(
            "{}",
            b.run_items(&name, batch as f64 * reads, || {
                noisy.matmul_keyed(&xb, &keys).len()
            })
            .report()
        );
    }
    pool::set_max_threads(0);
    pool::restart();

    // --- dispatch overhead: persistent pool vs per-call scoped spawn -------
    // near-empty chunks, so the number measured is the dispatch machinery
    // itself — the cost that dominated small digital batches on the
    // serving path before the pool (§Perf `spawn_overhead` rows; the
    // pooled/scoped ratio is the win of this change).  The cap is pinned
    // per width so the pooled side really dispatches `threads` lanes
    // even on a smaller machine — same width as the scoped reference.
    for threads in [2usize, 4, 8] {
        pool::set_max_threads(threads);
        pool::restart();
        pool::prewarm(threads);
        println!(
            "{}",
            b.run_items(
                &format!("spawn_overhead_pooled_t{threads} (dispatches/s)"),
                1.0,
                || pool::run_chunks(threads, threads, |r| r.sum::<usize>())
                    .iter()
                    .sum::<usize>()
            )
            .report()
        );
        println!(
            "{}",
            b.run_items(
                &format!("spawn_overhead_scoped_t{threads} (dispatches/s)"),
                1.0,
                || pool::run_chunks_scoped(threads, threads, |r| r.sum::<usize>())
                    .iter()
                    .sum::<usize>()
            )
            .report()
        );
    }
    pool::set_max_threads(0);
    pool::restart();

    // --- sharded serving: replicas 1/2/4 over the shared admission queue --
    // a 64-request closed-loop burst through the full server path
    // (admission stamp -> shared-queue batch assembly -> replica engine ->
    // response); the r1 -> r4 series is the §Serving shard-scaling row.
    // The toy engine runs full depth on every request, so served work per
    // request is constant and the delta is the serving layer itself.
    let burst = 64usize;
    let sample: Vec<f32> = (0..BT_DIM).map(|i| (i as f32 * 0.1).sin()).collect();
    for replicas in [1usize, 2, 4] {
        let srv = Server::start(
            || Ok(bench_toy_engine()),
            ServerConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_cap: 1024,
                replicas,
                ..Default::default()
            },
        );
        let client = srv.client();
        println!(
            "{}",
            b.run_items(
                &format!("serve_toy_r{replicas} (requests/s)"),
                burst as f64,
                || {
                    let waiters: Vec<_> = (0..burst)
                        .map(|_| client.submit(sample.clone()).unwrap())
                        .collect();
                    waiters
                        .into_iter()
                        .map(|w| w.recv().unwrap().outcome.unwrap().class)
                        .sum::<usize>()
                }
            )
            .report()
        );
        drop(client);
        srv.shutdown().unwrap();
    }

    // --- observability overhead: same burst with tracing + interim
    // snapshots on vs everything off — the obs_on/obs_off delta is the
    // whole cost of per-request traces (ring pushes, per-round cost
    // attribution) plus the live emitter, and is the §Perf row that keeps
    // "observes, never influences" honest on the throughput axis too.
    for (tag, trace) in [("off", false), ("on", true)] {
        let srv = Server::start(
            || Ok(bench_toy_engine()),
            ServerConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_cap: 1024,
                replicas: 1,
                trace,
                metrics_interval: trace.then(|| Duration::from_millis(100)),
                ..Default::default()
            },
        );
        let client = srv.client();
        let ring = srv.trace_ring();
        println!(
            "{}",
            b.run_items(
                &format!("serve_toy_obs_{tag} (requests/s)"),
                burst as f64,
                || {
                    let waiters: Vec<_> = (0..burst)
                        .map(|_| client.submit(sample.clone()).unwrap())
                        .collect();
                    let sum = waiters
                        .into_iter()
                        .map(|w| w.recv().unwrap().outcome.unwrap().class)
                        .sum::<usize>();
                    // drain between iterations so the ring never saturates
                    // (a full ring would short-circuit the push path)
                    if let Some(r) = &ring {
                        let _ = r.drain();
                    }
                    sum
                }
            )
            .report()
        );
        drop(client);
        srv.shutdown().unwrap();
    }

    // --- im2col on the stem geometry --------------------------------------
    let img: Vec<f32> = (0..8 * 28 * 28 * 16).map(|i| (i % 9) as f32).collect();
    println!(
        "{}",
        b.run("im2col_8x28x28x16_3x3", || {
            ops::im2col(&img, 8, 28, 28, 16, 3, 3, 1).0.len()
        })
        .report()
    );

    // --- GroupNorm + ReLU (digital peripherals) ---------------------------
    let mut feat: Vec<f32> = (0..8 * 28 * 28 * 16).map(|i| (i % 13) as f32).collect();
    let gamma = vec![1f32; 16];
    let beta = vec![0f32; 16];
    println!(
        "{}",
        b.run("group_norm_8x784x16", || {
            ops::group_norm(&mut feat, 8, 784, 16, 4, &gamma, &beta, 1e-5);
            feat[0]
        })
        .report()
    );

    // --- dense digital matmul (XLA-backend comparison point) --------------
    let wx: Vec<f32> = (0..144 * 16).map(|i| ((i % 3) as f32) - 1.0).collect();
    let cols: Vec<f32> = (0..8 * 784 * 144).map(|i| (i % 5) as f32).collect();
    println!(
        "{}",
        b.run_items(
            "digital_matmul_6272x144x16 (MACs/s)",
            (8 * 784 * 144 * 16) as f64,
            || ops::matmul(&cols, &wx, 8 * 784, 144, 16)[0]
        )
        .report()
    );

    // --- bit-packed ternary MVM vs the dense f32 kernel -------------------
    // integer activations, so the packed row takes the AND+popcount plane
    // path and both rows compute bit-identical outputs — the speedup is
    // pure kernel (EXPERIMENTS.md §Perf `mvm_packed_vs_dense` series)
    for (k, n) in [(512usize, 256usize), (2048usize, 1024usize)] {
        let mut wrng = Pcg64::new(7);
        let wt: Vec<i8> = (0..k * n).map(|_| [-1i8, 0, 1][wrng.below(3)]).collect();
        let wf: Vec<f32> = wt.iter().map(|&v| v as f32).collect();
        let pt = PackedTernary::pack(&wt, k, n);
        let xi: Vec<f32> = (0..k).map(|i| (i as i64 % 17 - 8) as f32).collect();
        let macs = (k * n) as f64;
        println!(
            "{}",
            b.run_items(&format!("mvm_packed_{k}x{n} (MACs/s)"), macs, || {
                pt.matmul(&xi, 1)[0]
            })
            .report()
        );
        println!(
            "{}",
            b.run_items(&format!("mvm_dense_{k}x{n} (MACs/s)"), macs, || {
                ops::matmul(&xi, &wf, 1, k, n)[0]
            })
            .report()
        );
    }

    // --- compiled step program vs tree walk (hlo::plan) -------------------
    // a DUS-heavy 192-iteration loop — the shape the plan targets: per-
    // instruction movable/drop decisions are precomputed once instead of
    // recomputed every iteration (EXPERIMENTS.md §Perf `hlo_while_dus`
    // series); both rows compute identical bits (parity-gated in tests)
    let loop_text = "HloModule bench_loop
cond.1 {
  p.2 = (f32[256], s32[]) parameter(0)
  i.3 = s32[] get-tuple-element(p.2), index=1
  c.4 = s32[] constant(192)
  ROOT lt.5 = pred[] compare(i.3, c.4), direction=LT
}
body.6 {
  p.7 = (f32[256], s32[]) parameter(0)
  b.8 = f32[256] get-tuple-element(p.7), index=0
  i.9 = s32[] get-tuple-element(p.7), index=1
  u.10 = f32[8] constant({1, 2, 3, 4, 5, 6, 7, 8})
  d.11 = f32[256] dynamic-update-slice(b.8, u.10, i.9)
  c.12 = s32[] constant(1)
  ni.13 = s32[] add(i.9, c.12)
  ROOT t.14 = (f32[256], s32[]) tuple(d.11, ni.13)
}
ENTRY main.15 {
  x.16 = f32[256] parameter(0)
  z.17 = s32[] constant(0)
  t.18 = (f32[256], s32[]) tuple(x.16, z.17)
  w.19 = (f32[256], s32[]) while(t.18), condition=cond.1, body=body.6
  ROOT g.20 = f32[256] get-tuple-element(w.19), index=0
}
";
    let module = memdyn::hlo::parse(loop_text).expect("bench module parses");
    let interp = memdyn::hlo::Interpreter::new(module).expect("bench module verifies");
    let loop_arg = [memdyn::hlo::Value::arr(memdyn::hlo::ArrayVal {
        shape: vec![256],
        data: memdyn::hlo::Data::F32(vec![0.0; 256]),
    })];
    for (tag, on) in [("planned", true), ("tree", false)] {
        memdyn::hlo::plan::set_enabled(on);
        println!(
            "{}",
            b.run_items(
                &format!("hlo_while_dus_192_{tag} (iters/s)"),
                192.0,
                || {
                    let v = interp.run_entry(&loop_arg).unwrap();
                    v.as_arr().unwrap().elements()
                }
            )
            .report()
        );
    }
    memdyn::hlo::plan::set_enabled(true);

    // --- load-time static verification (hlo::verify) ----------------------
    // full load path (parse + verify + plan compile) with the verifier on
    // vs off — the explicit cost of the two static passes.  Load rides the
    // per-path executable cache, so on the serve path this amortizes to
    // zero; the steady-state serve rows above must stay within noise of
    // each other regardless of this toggle (asserted by the determinism
    // sweep, measured here).
    for (tag, on) in [("on", true), ("off", false)] {
        memdyn::hlo::verify::set_enabled(on);
        println!(
            "{}",
            b.run(&format!("hlo_load_verify_{tag}"), || {
                let m = memdyn::hlo::parse(loop_text).expect("bench module parses");
                let i = memdyn::hlo::Interpreter::new(m).expect("bench module verifies");
                i.module().comps.len()
            })
            .report()
        );
    }
    memdyn::hlo::verify::set_enabled(true);

    // --- CAM search --------------------------------------------------------
    let centers: Vec<i8> = (0..10 * 32).map(|_| [-1i8, 0, 1][rng.below(3)]).collect();
    let bank = memdyn::cam::CamBank::program(
        &centers,
        10,
        32,
        &DeviceConfig::default(),
        &ConverterConfig::default(),
        &mut rng,
    );
    let sv: Vec<f32> = (0..32).map(|i| (i as f32 * 0.3).cos()).collect();
    println!(
        "{}",
        b.run("cam_search_10x32_noisy", || bank.search(&sv, &mut rng2).class)
            .report()
    );
}
