//! Fig. 4 bench: device characterization statistics + the write/read-noise
//! accuracy sweeps (ternary vs direct-FP mapping), plus programming
//! throughput of the crossbar substrate.

use memdyn::cim::CimMatrix;
use memdyn::crossbar::ConverterConfig;
use memdyn::device::DeviceConfig;
use memdyn::figures::common::Setup;
use memdyn::model::artifacts_dir;
use memdyn::util::bench::standard_bencher;
use memdyn::util::rng::Pcg64;

fn main() {
    let b = standard_bencher("fig4: memristor noise + ternary defence");
    let mut rng = Pcg64::new(3);

    // programming throughput (device writes/s) — program-verify ablation
    let (k, n) = (512usize, 128usize);
    let w: Vec<i8> = (0..k * n).map(|_| [-1i8, 0, 1][rng.below(3)]).collect();
    println!(
        "{}",
        b.run_items("program_512x128 (device writes/s)", (k * 2 * n) as f64, || {
            CimMatrix::program(
                &w,
                k,
                n,
                &DeviceConfig::default(),
                &ConverterConfig::default(),
                &mut rng,
            )
            .tile_count()
        })
        .report()
    );

    let dir = artifacts_dir(None);
    if !dir.join("index.json").exists() {
        println!("SKIP fig4 accuracy sweeps: no artifacts");
        return;
    }
    let samples = std::env::var("MEMDYN_BENCH_SAMPLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60);
    let setup = Setup::new(&dir, samples);
    for fig in ["4a", "4bcde", "4f", "4g", "4h", "4i"] {
        let t0 = std::time::Instant::now();
        match memdyn::figures::run(fig, &setup) {
            Ok(text) => {
                println!("{text}");
                println!("[fig {fig}: {:.1}s]\n", t0.elapsed().as_secs_f64());
            }
            Err(e) => println!("[fig {fig} FAILED: {e:#}]"),
        }
    }
}
