//! Thread-count determinism: the parallel engine must produce outputs
//! bit-identical to the sequential path for the same seed at 1, 2 and 8
//! threads.  Runs on a fully synthetic analogue model (crossbar-backed
//! layers + analogue CAM) so it needs no artifacts and exercises the whole
//! keyed noise chain: per-request streams -> per-layer ids -> per-tile
//! derivation -> CAM search keys.
//!
//! The pooled sweep below additionally locks down the persistent worker
//! pool: logits, exit decisions *and CIM energy counters* are
//! bit-identical at every width, across `MEMDYN_THREADS`, and across a
//! pool restart within one process.
//!
//! The sharded-serving sweep extends the same guarantee across the
//! replica axis: the same request stream through `Server` at 1, 2 and 4
//! replicas must reproduce the direct single-engine run bit-for-bit —
//! outcomes *and* the CIM/CAM energy counters summed over all replica
//! engines — because request ids are stamped at admission, not by the
//! shard that happens to win the request.
//!
//! The continuous-batching sweeps extend it across the *scheduling* axis:
//! a back-fill-heavy pre-loaded workload (early exits vacate slots
//! mid-flight, queued requests back-fill them — asserted via
//! `Snapshot.backfills`) and arrival-order shuffles of the same
//! (ticket id, input) bindings must both reproduce the reference run
//! bit-for-bit.  What cohort a request lands in is timing; what it
//! computes is (id, input, model).  See docs/SERVING.md.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use memdyn::cam::SemanticMemory;
use memdyn::coordinator::dynmodel::DynModel;
use memdyn::coordinator::memory::{ExitMemory, ExitStats};
use memdyn::coordinator::{Engine, Server, ServerConfig};
use memdyn::crossbar::ConverterConfig;
use memdyn::device::DeviceConfig;
use memdyn::nn::weights::{MvmKeys, NoiseSpec, WeightMatrix};
use memdyn::util::rng::{str_id, Pcg64, StreamKey};

const DIM: usize = 24;
const BLOCKS: usize = 3;
const CLASSES: usize = 4;

/// A miniature dynamic network living entirely on the noisy crossbar
/// substrate: each block emits the current feature row as its search
/// vector, then pushes it through one analogue `(DIM, DIM)` layer.
struct XbarToy {
    layers: Vec<WeightMatrix>,
    key: StreamKey,
}

struct XbarState {
    rows: Vec<Vec<f32>>,
    keys: Vec<StreamKey>,
}

impl XbarToy {
    fn build(seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let spec = NoiseSpec::paper_default();
        let layers = (0..BLOCKS)
            .map(|i| {
                let w: Vec<i8> =
                    (0..DIM * DIM).map(|_| [-1i8, 0, 1][rng.below(3)]).collect();
                WeightMatrix::from_ternary(&w, DIM, DIM, &spec, &mut rng)
                    .with_stream_id(str_id(&format!("xbar_toy.{i}")))
            })
            .collect();
        XbarToy {
            layers,
            key: StreamKey::root(seed ^ 0xabcd),
        }
    }
}

impl DynModel for XbarToy {
    type State = XbarState;

    fn n_blocks(&self) -> usize {
        BLOCKS
    }

    fn classes(&self) -> usize {
        CLASSES
    }

    fn init(&self, input: &[f32], batch: usize, reqs: &[u64]) -> Result<XbarState> {
        Ok(XbarState {
            rows: (0..batch)
                .map(|i| input[i * DIM..(i + 1) * DIM].to_vec())
                .collect(),
            keys: reqs.iter().map(|&r| self.key.child(r)).collect(),
        })
    }

    fn step(&self, i: usize, state: &mut XbarState) -> Result<Vec<f32>> {
        let mut svs = Vec::with_capacity(state.rows.len() * DIM);
        for (row, key) in state.rows.iter_mut().zip(&state.keys) {
            // the raw row is this block's search vector; the analogue layer
            // then advances the state (bounded to keep activations tame)
            svs.extend_from_slice(row);
            let sample_keys = [*key];
            let y = self.layers[i].matmul(row, 1, &MvmKeys::per_sample(&sample_keys));
            *row = y.iter().map(|v| v.clamp(-4.0, 4.0) * 0.5).collect();
        }
        Ok(svs)
    }

    fn batch_of(&self, state: &XbarState) -> usize {
        state.rows.len()
    }

    fn select(&self, state: &XbarState, keep: &[usize]) -> XbarState {
        XbarState {
            rows: keep.iter().map(|&r| state.rows[r].clone()).collect(),
            keys: keep.iter().map(|&r| state.keys[r]).collect(),
        }
    }

    fn finish(&self, state: &XbarState) -> Result<Vec<f32>> {
        Ok(state
            .rows
            .iter()
            .flat_map(|r| r[..CLASSES].to_vec())
            .collect())
    }

    fn row_cost(&self, block: usize) -> memdyn::cim::CimCounters {
        // each live row does exactly one MVM through this block's layer
        // per round, so the analytic per-row cost is the layer's tile
        // geometry — the serving trace/snapshot energy attribution must
        // then sum to the *harvested* crossbar counters exactly
        self.layers[block].mvm_cost()
    }
}

/// Ternary centers for one exit, shared between the CAM and the test
/// inputs so the exit mix is constructed, not hoped for.
fn exit_centers(exit: u64) -> Vec<i8> {
    let mut rng = Pcg64::new(1000 + exit);
    let mut c: Vec<i8> = (0..CLASSES * DIM)
        .map(|_| [-1i8, 0, 1][rng.below(3)])
        .collect();
    for cc in 0..CLASSES {
        c[cc * DIM] = 1; // no all-zero centers
    }
    c
}

fn analog_memory(seed: u64) -> ExitMemory {
    let mut rng = Pcg64::new(seed);
    let exits: Vec<(Vec<i8>, usize, usize)> = (0..BLOCKS)
        .map(|e| (exit_centers(e as u64), CLASSES, DIM))
        .collect();
    let mem = SemanticMemory::program(
        &exits,
        &DeviceConfig::default(),
        &ConverterConfig::default(),
        &mut rng,
    );
    ExitMemory::Analog {
        mem,
        stats: (0..BLOCKS).map(|_| ExitStats::identity(DIM)).collect(),
        key: StreamKey::root(seed ^ 0x5eed),
    }
}

fn engine(threads: usize) -> Engine<XbarToy> {
    // 0.7: samples planted on an exit-0 center clear it comfortably
    // (stored-pattern cosine ~1 under the default noise), uniform-random
    // rows essentially never do (24-dim random cosine ~N(0, 0.2))
    Engine::new(XbarToy::build(99), analog_memory(31), vec![0.7; BLOCKS])
        .with_threads(threads)
}

/// Even samples sit exactly on an exit-0 center (guaranteed early exit);
/// odd samples are uniform random (reach the head).
fn inputs(n: usize) -> Vec<f32> {
    let centers = exit_centers(0);
    let mut rng = Pcg64::new(7);
    let mut xs = Vec::with_capacity(n * DIM);
    for i in 0..n {
        if i % 2 == 0 {
            let class = (i / 2) % CLASSES;
            xs.extend(
                centers[class * DIM..(class + 1) * DIM]
                    .iter()
                    .map(|&v| v as f32),
            );
        } else {
            xs.extend((0..DIM).map(|_| rng.uniform_in(-1.0, 1.0) as f32));
        }
    }
    xs
}

#[test]
fn parallel_engine_is_bit_identical_to_sequential() {
    let n = 13;
    let xs = inputs(n);
    let want = engine(1).infer_batch(&xs, n).unwrap();
    // sanity: the synthetic setup exercises both exit paths
    assert!(want.iter().any(|o| o.exited_early), "no early exits");
    assert!(want.iter().any(|o| !o.exited_early), "no head exits");
    for threads in [2usize, 8] {
        let got = engine(threads).infer_batch(&xs, n).unwrap();
        assert_eq!(got.len(), want.len());
        for (i, (a, b)) in want.iter().zip(&got).enumerate() {
            assert_eq!(a.class, b.class, "sample {i}, {threads} threads");
            assert_eq!(a.exit, b.exit, "sample {i}, {threads} threads");
            assert_eq!(
                a.exited_early, b.exited_early,
                "sample {i}, {threads} threads"
            );
            assert!(
                a.similarity == b.similarity
                    || (a.similarity.is_nan() && b.similarity.is_nan()),
                "sample {i}, {threads} threads: {} vs {}",
                a.similarity,
                b.similarity
            );
        }
    }
}

#[test]
fn parallel_trace_matches_sequential_bitwise() {
    // record_trace runs the full backbone: logits (head preds) and every
    // per-exit similarity must be bit-identical across thread counts
    let n = 11;
    let xs = inputs(n);
    let labels: Vec<i32> = (0..n as i32).map(|i| i % CLASSES as i32).collect();
    let want = engine(1).record_trace(&xs, DIM, &labels, 4).unwrap();
    for threads in [2usize, 8] {
        let got = engine(threads).record_trace(&xs, DIM, &labels, 4).unwrap();
        assert_eq!(want.sims, got.sims, "{threads} threads: sims diverged");
        assert_eq!(want.preds, got.preds, "{threads} threads: preds diverged");
        assert_eq!(
            want.final_pred, got.final_pred,
            "{threads} threads: head logits diverged"
        );
    }
}

/// Total device-usage counters across every analogue surface the toy
/// model touches (3 crossbar layers + the analogue CAM).  Drains the
/// counters, so call exactly once per engine run.
fn energy(e: &Engine<XbarToy>) -> memdyn::cim::CimCounters {
    let mut total = memdyn::cim::CimCounters::default();
    for layer in &e.model.layers {
        total.add(&layer.take_counters());
    }
    total.add(&e.memory.take_counters());
    total
}

fn assert_outcomes_eq(
    want: &[memdyn::coordinator::engine::Outcome],
    got: &[memdyn::coordinator::engine::Outcome],
    tag: &str,
) {
    assert_eq!(want.len(), got.len(), "{tag}: batch size");
    for (i, (a, b)) in want.iter().zip(got).enumerate() {
        assert_eq!(a.class, b.class, "{tag}: sample {i} class");
        assert_eq!(a.exit, b.exit, "{tag}: sample {i} exit");
        assert_eq!(a.exited_early, b.exited_early, "{tag}: sample {i} early");
        assert!(
            a.similarity == b.similarity
                || (a.similarity.is_nan() && b.similarity.is_nan()),
            "{tag}: sample {i} similarity {} vs {}",
            a.similarity,
            b.similarity
        );
    }
}

#[test]
fn pooled_thread_sweep_is_bit_identical_including_energy_counters() {
    let n = 12;
    let xs = inputs(n);
    let seq = engine(1);
    let want = seq.infer_batch(&xs, n).unwrap();
    assert!(want.iter().any(|o| o.exited_early), "no early exits");
    assert!(want.iter().any(|o| !o.exited_early), "no head exits");
    let want_energy = energy(&seq);
    assert!(want_energy.mvms > 0, "toy model must touch the crossbars");
    for threads in [2usize, 4, 8] {
        let par = engine(threads);
        let got = par.infer_batch(&xs, n).unwrap();
        assert_outcomes_eq(&want, &got, &format!("{threads} threads"));
        assert_eq!(
            energy(&par),
            want_energy,
            "{threads} threads: CIM energy counters diverged"
        );
    }
}

#[test]
fn pool_restart_within_process_preserves_results() {
    let n = 10;
    let xs = inputs(n);
    let before_engine = engine(4);
    let before = before_engine.infer_batch(&xs, n).unwrap();
    let before_energy = energy(&before_engine);
    // tear the pool down mid-process; the next dispatch respawns lazily
    memdyn::util::pool::restart();
    let after_engine = engine(4);
    let after = after_engine.infer_batch(&xs, n).unwrap();
    let after_energy = energy(&after_engine);
    assert_outcomes_eq(&before, &after, "after pool restart");
    assert_eq!(before_energy, after_energy, "energy counters after restart");
}

#[test]
fn worker_cap_sweep_is_bit_identical() {
    // pool::set_max_threads is the MEMDYN_THREADS cap minus the env read
    // (env::set_var would race with concurrent env::var readers in this
    // multi-threaded test binary).  Every cap in {1, 2, 4, 8} must
    // produce the same bits: the cap affects scheduling only.
    let n = 10;
    let xs = inputs(n);
    memdyn::util::pool::set_max_threads(1);
    let seq = engine(4);
    let want = seq.infer_batch(&xs, n).unwrap();
    let want_energy = energy(&seq);
    for cap in [2usize, 4, 8] {
        memdyn::util::pool::set_max_threads(cap);
        // restart so the worker set is re-grown under the new cap
        memdyn::util::pool::restart();
        let par = engine(4);
        let got = par.infer_batch(&xs, n).unwrap();
        assert_outcomes_eq(&want, &got, &format!("worker cap {cap}"));
        assert_eq!(
            energy(&par),
            want_energy,
            "worker cap {cap}: CIM energy counters diverged"
        );
    }
    memdyn::util::pool::set_max_threads(0);
    memdyn::util::pool::restart();
}

/// The tentpole guarantee of the sharded server: for one submitted
/// request stream, outcomes and total analogue device usage are
/// bit-identical at 1, 2 and 4 replicas, and equal to the direct
/// single-engine run.  Ids are stamped at admission (submission order),
/// so whichever replica wins a request derives the same noise streams;
/// each replica's programmed arrays are identical because the factory is
/// deterministic.  Energy is harvested per replica via the server's
/// finalizer hook and summed — batching and shard assignment may differ
/// arbitrarily between runs, the totals must not.
#[test]
fn sharded_serving_is_bit_identical_across_replica_counts() {
    let n = 16;
    let xs = inputs(n);
    // reference: a fresh engine allocates ids 0..n, exactly what the
    // admission counter stamps for n sequential submissions
    let reference = engine(1);
    let want = reference.infer_batch(&xs, n).unwrap();
    assert!(want.iter().any(|o| o.exited_early), "no early exits");
    assert!(want.iter().any(|o| !o.exited_early), "no head exits");
    let want_energy = energy(&reference);
    assert!(want_energy.mvms > 0, "reference run must touch the crossbars");

    for replicas in [1usize, 2, 4] {
        let sink = Arc::new(Mutex::new(memdyn::cim::CimCounters::default()));
        let sink2 = Arc::clone(&sink);
        // observability must observe without influencing: run the whole
        // sweep with per-request tracing AND live interim snapshots on —
        // outcomes and energy counters must still be bit-identical
        let srv = Server::start_with_finalizer(
            move || Ok(engine(1)),
            move |e: Engine<XbarToy>| sink2.lock().unwrap().add(&energy(&e)),
            ServerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
                queue_cap: 64,
                replicas,
                trace: true,
                metrics_interval: Some(Duration::from_millis(25)),
                ..Default::default()
            },
        );
        let client = srv.client();
        let waiters: Vec<_> = (0..n)
            .map(|i| client.submit(xs[i * DIM..(i + 1) * DIM].to_vec()).unwrap())
            .collect();
        let got: Vec<_> = waiters
            .into_iter()
            .map(|w| w.recv().unwrap().outcome.unwrap())
            .collect();
        drop(client);
        let ring = srv.trace_ring().expect("tracing is on");
        let snap = srv.shutdown().unwrap();
        assert_eq!(snap.requests, n as u64, "{replicas} replicas");
        assert_eq!(snap.errors, 0, "{replicas} replicas");
        assert_outcomes_eq(&want, &got, &format!("{replicas} replicas"));
        let total = *sink.lock().unwrap();
        assert_eq!(
            total, want_energy,
            "{replicas} replicas: CIM/CAM energy counters diverged"
        );
        // the snapshot's analytic per-request attribution (row_cost +
        // search_cost per live row per round) must equal the harvested
        // crossbar counters exactly: every MVM the engines actually ran
        // is charged to exactly one request
        let mut attributed = snap.cim_energy;
        attributed.add(&snap.cam_energy);
        assert_eq!(
            attributed, total,
            "{replicas} replicas: analytic energy attribution diverged from harvested counters"
        );
        // every request left exactly one trace, each with exit+1 rounds
        let (traces, dropped) = ring.drain();
        assert_eq!(dropped, 0, "{replicas} replicas: ring overflowed");
        assert_eq!(traces.len(), n, "{replicas} replicas: trace count");
        for t in &traces {
            let exit = t.exit.as_ref().expect("finished trace has an exit").block;
            assert_eq!(
                t.rounds.len(),
                exit + 1,
                "{replicas} replicas: request {} round count",
                t.id
            );
        }
    }
}

/// The continuous-batching headline test: a back-fill-heavy workload —
/// the whole stream pre-loaded while workers are parked in a gated
/// factory, so every block-0 early exit is guaranteed to find queued
/// requests to back-fill its slot with — reproduces the reference run
/// bit-for-bit (outcomes and summed energy) at 1, 2 and 4 replicas, and
/// the single-replica run provably back-fills (`Snapshot.backfills`).
/// Back-fill changes *when* a request runs and *what cohort* it shares;
/// admission-stamped ids mean it must never change what it computes.
#[test]
fn backfill_heavy_serving_is_bit_identical_and_actually_backfills() {
    let n = 24;
    let xs = inputs(n);
    let reference = engine(1);
    let want = reference.infer_batch(&xs, n).unwrap();
    assert!(want.iter().any(|o| o.exited_early), "no early exits");
    assert!(want.iter().any(|o| !o.exited_early), "no head exits");
    let want_energy = energy(&reference);

    for replicas in [1usize, 2, 4] {
        let sink = Arc::new(Mutex::new(memdyn::cim::CimCounters::default()));
        let sink2 = Arc::clone(&sink);
        let gate = Arc::new(AtomicBool::new(false));
        let gate2 = Arc::clone(&gate);
        let srv = Server::start_with_finalizer(
            move || {
                // park until the test has pre-loaded the queue
                while !gate2.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Ok(engine(1))
            },
            move |e: Engine<XbarToy>| sink2.lock().unwrap().add(&energy(&e)),
            ServerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
                queue_cap: 64,
                replicas,
                // tracing on for the back-fill-heavy path too: the
                // admitted spans of back-filled requests carry
                // backfill=true, and none of it may perturb the bits
                trace: true,
                ..Default::default()
            },
        );
        let client = srv.client();
        let waiters: Vec<_> = (0..n)
            .map(|i| client.submit(xs[i * DIM..(i + 1) * DIM].to_vec()).unwrap())
            .collect();
        gate.store(true, Ordering::SeqCst);
        let got: Vec<_> = waiters
            .into_iter()
            .map(|w| w.recv().unwrap().outcome.unwrap())
            .collect();
        drop(client);
        let ring = srv.trace_ring().expect("tracing is on");
        let snap = srv.shutdown().unwrap();
        assert_eq!(snap.requests, n as u64, "{replicas} replicas");
        assert_eq!(snap.errors, 0, "{replicas} replicas");
        assert_outcomes_eq(&want, &got, &format!("backfill, {replicas} replicas"));
        let total = *sink.lock().unwrap();
        assert_eq!(
            total, want_energy,
            "{replicas} replicas: CIM/CAM energy counters diverged under back-fill"
        );
        let mut attributed = snap.cim_energy;
        attributed.add(&snap.cam_energy);
        assert_eq!(
            attributed, total,
            "{replicas} replicas: analytic attribution diverged under back-fill"
        );
        let (traces, dropped) = ring.drain();
        assert_eq!(dropped, 0, "{replicas} replicas: ring overflowed");
        assert_eq!(traces.len(), n, "{replicas} replicas: trace count");
        if replicas == 1 {
            // single worker, queue pre-loaded with 24, max_batch 4, and
            // the even samples exit at block 0 by construction: the free
            // slots MUST be back-filled (no timing assumption — the
            // worker's try_lock admission cannot contend with anyone)
            assert!(
                snap.backfills >= 1,
                "pre-loaded early-exit workload did not back-fill: {snap:?}"
            );
            // ...and the back-filled requests' traces say so
            assert!(
                traces.iter().any(|t| t.backfill),
                "back-fills happened but no trace carries backfill=true"
            );
        }
    }
}

/// Arrival-order invariance: stamp tickets in id order, enqueue them in a
/// shuffled order (ticket i always bound to input i), and the outcomes
/// collected per ticket id — plus the energy totals — must reproduce the
/// reference run exactly.  This is the determinism line drawn precisely:
/// queue order, batch composition, and shard assignment all change under
/// the shuffle; every computed bit must not.
#[test]
fn arrival_order_shuffle_preserves_outcomes_and_energy() {
    let n = 16;
    let xs = inputs(n);
    let reference = engine(1);
    let want = reference.infer_batch(&xs, n).unwrap();
    let want_energy = energy(&reference);
    let mut rng = Pcg64::new(4242);

    for trial in 0..3 {
        let sink = Arc::new(Mutex::new(memdyn::cim::CimCounters::default()));
        let sink2 = Arc::clone(&sink);
        let srv = Server::start_with_finalizer(
            move || Ok(engine(1)),
            move |e: Engine<XbarToy>| sink2.lock().unwrap().add(&energy(&e)),
            ServerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
                queue_cap: 64,
                replicas: 2,
                ..Default::default()
            },
        );
        let client = srv.client();
        let mut tickets: Vec<Option<memdyn::coordinator::Ticket>> =
            (0..n).map(|_| Some(client.stamp())).collect();
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut waiters: Vec<Option<_>> = (0..n).map(|_| None).collect();
        for &i in &order {
            let t = tickets[i].take().unwrap();
            assert_eq!(t.id(), i as u64, "stamp order is id order");
            waiters[i] = Some(
                client
                    .submit_ticket(t, xs[i * DIM..(i + 1) * DIM].to_vec())
                    .unwrap(),
            );
        }
        let got: Vec<_> = waiters
            .into_iter()
            .map(|w| w.unwrap().recv().unwrap().outcome.unwrap())
            .collect();
        drop(client);
        let snap = srv.shutdown().unwrap();
        assert_eq!(snap.requests, n as u64, "trial {trial}");
        assert_eq!(snap.errors, 0, "trial {trial}");
        assert_outcomes_eq(&want, &got, &format!("shuffle trial {trial}"));
        let total = *sink.lock().unwrap();
        assert_eq!(
            total, want_energy,
            "trial {trial}: CIM/CAM energy counters diverged under shuffle"
        );
    }
}

#[test]
fn packed_kernel_toggle_is_invisible_to_outcomes_and_energy() {
    // The bit-packed ternary kernel lives on the exact/mean paths only;
    // the noisy analogue substrate (keyed crossbar MVMs + CAM search)
    // must be untouched by the toggle: outcomes AND CIM energy counters
    // bit-identical with packing on vs off.
    let n = 12;
    let xs = inputs(n);
    memdyn::cim::packed::set_enabled(true);
    let on_engine = engine(1);
    let on = on_engine.infer_batch(&xs, n).unwrap();
    let on_energy = energy(&on_engine);
    assert!(on_energy.mvms > 0, "toy model must touch the crossbars");
    memdyn::cim::packed::set_enabled(false);
    let off_engine = engine(1);
    let off = off_engine.infer_batch(&xs, n).unwrap();
    let off_energy = energy(&off_engine);
    memdyn::cim::packed::set_enabled(true);
    assert_outcomes_eq(&on, &off, "packing off");
    assert_eq!(on_energy, off_energy, "packing toggled the energy counters");

    // And on a surface where packing IS active (ideal-device mean path):
    // same bits with the kernel on and off — integer activations make
    // both the popcount kernel and the tile loop exact — and zero
    // counter deltas either way (the mean path is free by construction).
    let mut rng = Pcg64::new(55);
    let w: Vec<i8> = (0..DIM * DIM).map(|_| [-1i8, 0, 1][rng.below(3)]).collect();
    let cim = memdyn::cim::CimMatrix::program(
        &w,
        DIM,
        DIM,
        &DeviceConfig::ideal(),
        &ConverterConfig::ideal(),
        &mut rng,
    );
    assert!(cim.is_packed(), "ideal device must build the packed form");
    let x: Vec<f32> = (0..2 * DIM).map(|i| (i as i64 % 5 - 2) as f32).collect();
    let y_on = cim.matmul_mean(&x, 2);
    memdyn::cim::packed::set_enabled(false);
    let y_off = cim.matmul_mean(&x, 2);
    memdyn::cim::packed::set_enabled(true);
    assert_eq!(y_on, y_off, "mean-path bits changed with the packing toggle");
    assert_eq!(cim.take_counters(), memdyn::cim::CimCounters::default());
}

#[test]
fn plan_toggle_is_invisible_to_outcomes_and_energy() {
    // The compiled step program (hlo::plan) is an execution strategy for
    // the digital interpreter only; the analogue substrate never sees it.
    // Outcomes and CIM/CAM energy counters must be bit-identical with the
    // plan on vs off — same invariant the packed-kernel toggle holds.
    let n = 12;
    let xs = inputs(n);
    memdyn::hlo::plan::set_enabled(true);
    let on_engine = engine(1);
    let on = on_engine.infer_batch(&xs, n).unwrap();
    let on_energy = energy(&on_engine);
    assert!(on_energy.mvms > 0, "toy model must touch the crossbars");
    memdyn::hlo::plan::set_enabled(false);
    let off_engine = engine(1);
    let off = off_engine.infer_batch(&xs, n).unwrap();
    let off_energy = energy(&off_engine);
    memdyn::hlo::plan::set_enabled(true);
    assert_outcomes_eq(&on, &off, "plan off");
    assert_eq!(on_energy, off_energy, "plan toggled the energy counters");

    // And on a surface the plan DOES drive — an interpreter module with
    // a loop-carried buffer — the two strategies must agree bit-for-bit.
    let text = "HloModule t
cond.1 {
  p.2 = (f32[4], s32[]) parameter(0)
  i.3 = s32[] get-tuple-element(p.2), index=1
  c.4 = s32[] constant(3)
  ROOT lt.5 = pred[] compare(i.3, c.4), direction=LT
}
body.6 {
  p.7 = (f32[4], s32[]) parameter(0)
  b.8 = f32[4] get-tuple-element(p.7), index=0
  i.9 = s32[] get-tuple-element(p.7), index=1
  s.10 = f32[4] add(b.8, b.8)
  c.11 = s32[] constant(1)
  ni.12 = s32[] add(i.9, c.11)
  ROOT t.13 = (f32[4], s32[]) tuple(s.10, ni.12)
}
ENTRY main.14 {
  x.15 = f32[4] parameter(0)
  z.16 = s32[] constant(0)
  t.17 = (f32[4], s32[]) tuple(x.15, z.16)
  w.18 = (f32[4], s32[]) while(t.17), condition=cond.1, body=body.6
  ROOT g.19 = f32[4] get-tuple-element(w.18), index=0
}
";
    let m = memdyn::hlo::parse(text).unwrap();
    let interp = memdyn::hlo::Interpreter::new(m).unwrap();
    let arg = [memdyn::hlo::Value::arr(memdyn::hlo::ArrayVal {
        shape: vec![4],
        data: memdyn::hlo::Data::F32(vec![1.0, -2.0, 0.5, 3.0]),
    })];
    let planned = interp.run_entry(&arg).unwrap();
    let oracle = interp.run_entry_tree(&arg).unwrap();
    let get = |v: &memdyn::hlo::Value| match &v.as_arr().unwrap().data {
        memdyn::hlo::Data::F32(d) => d.clone(),
        other => panic!("expected f32, got {other:?}"),
    };
    assert_eq!(get(&planned), vec![8.0, -16.0, 4.0, 24.0]);
    assert_eq!(get(&planned), get(&oracle), "planned != tree-walk oracle");
}

#[test]
fn verify_toggle_is_invisible_to_outcomes_and_energy() {
    // Static verification (hlo::verify) is a load-time accept/reject
    // gate: it never rewrites the module or the plan, so outcomes and
    // energy must be bit-identical with the verifier on vs off — the
    // same invariant the plan and packed-kernel toggles hold.
    let n = 12;
    let xs = inputs(n);
    memdyn::hlo::verify::set_enabled(true);
    let on_engine = engine(1);
    let on = on_engine.infer_batch(&xs, n).unwrap();
    let on_energy = energy(&on_engine);
    memdyn::hlo::verify::set_enabled(false);
    let off_engine = engine(1);
    let off = off_engine.infer_batch(&xs, n).unwrap();
    let off_energy = energy(&off_engine);
    memdyn::hlo::verify::set_enabled(true);
    assert_outcomes_eq(&on, &off, "verify off");
    assert_eq!(on_energy, off_energy, "verify toggled the energy counters");

    // And on the interpreter surface: the same module built with the
    // verifier on and off produces the same bits (verification happens
    // before execution and touches nothing the evaluator reads).
    let text = "HloModule v
ENTRY main.1 {
  x.2 = f32[4] parameter(0)
  y.3 = f32[4] add(x.2, x.2)
  ROOT z.4 = f32[4] multiply(y.3, x.2)
}
";
    let arg = [memdyn::hlo::Value::arr(memdyn::hlo::ArrayVal {
        shape: vec![4],
        data: memdyn::hlo::Data::F32(vec![1.5, -2.0, 0.25, 3.0]),
    })];
    let verified = memdyn::hlo::Interpreter::new(memdyn::hlo::parse(text).unwrap())
        .unwrap()
        .run_entry(&arg)
        .unwrap();
    memdyn::hlo::verify::set_enabled(false);
    let unverified = memdyn::hlo::Interpreter::new(memdyn::hlo::parse(text).unwrap())
        .unwrap()
        .run_entry(&arg)
        .unwrap();
    memdyn::hlo::verify::set_enabled(true);
    let get = |v: &memdyn::hlo::Value| match &v.as_arr().unwrap().data {
        memdyn::hlo::Data::F32(d) => d.clone(),
        other => panic!("expected f32, got {other:?}"),
    };
    assert_eq!(get(&verified), vec![4.5, 8.0, 0.125, 18.0]);
    assert_eq!(get(&verified), get(&unverified), "verify toggle changed bits");
}

#[test]
fn batch_split_does_not_change_outcomes() {
    // the same samples inferred one-by-one (fresh engine, same ids) match
    // the batched run: noise is per-request, not per-batch-composition
    let n = 6;
    let xs = inputs(n);
    let batched = engine(1).infer_batch(&xs, n).unwrap();
    let e = engine(1);
    for (i, b) in batched.iter().enumerate() {
        let single = e.infer_batch(&xs[i * DIM..(i + 1) * DIM], 1).unwrap();
        assert_eq!(single[0].class, b.class, "sample {i}");
        assert_eq!(single[0].exit, b.exit, "sample {i}");
    }
}
