//! Conformance suite for the native HLO-text interpreter (`memdyn::hlo`).
//!
//! Three layers:
//!
//! 1. **Per-op unit tests** — one tiny hand-written HLO-text module per
//!    opcode family, no artifacts needed, so `cargo test` exercises the
//!    full op set on a fresh checkout.
//! 2. **Artifact census** (needs `make artifacts`) — every shipped
//!    `.hlo.txt` parses, and the set of opcodes they use is *exactly*
//!    [`memdyn::hlo::SUPPORTED_OPS`], so a regenerated artifact with a
//!    new opcode fails loudly instead of miscomputing.
//! 3. **Parity** (needs `make artifacts`) — the `--backend xla`
//!    interpreter path reproduces the native digital-path forward within
//!    1e-4 (relative) on the bundled MNIST samples, and is bucket-padding
//!    consistent on the bundled ModelNet samples.

use std::collections::BTreeSet;
use std::path::PathBuf;

use memdyn::coordinator::dynmodel::{DynModel, XlaPointNetModel, XlaResNetModel};
use memdyn::hlo::{ArrayVal, Data, Interpreter, parse, SUPPORTED_OPS, Value};
use memdyn::model::{DatasetBundle, ModelBundle};
use memdyn::nn::resnet::WeightSource;
use memdyn::nn::{NativeResNet, NoiseSpec};
use memdyn::runtime::Runtime;
use memdyn::util::rng::{Pcg64, StreamKey};

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

fn vf32(shape: &[usize], data: Vec<f32>) -> Value {
    Value::arr(ArrayVal {
        shape: shape.to_vec(),
        data: Data::F32(data),
    })
}

fn vs32(shape: &[usize], data: Vec<i32>) -> Value {
    Value::arr(ArrayVal {
        shape: shape.to_vec(),
        data: Data::S32(data),
    })
}

fn run(text: &str, inputs: &[Value]) -> Value {
    let m = parse(text).expect("module should parse");
    Interpreter::new(m)
        .expect("module should verify")
        .run_entry(inputs)
        .expect("module should evaluate")
}

fn out_f32(v: &Value) -> Vec<f32> {
    match &v.as_arr().expect("array result").data {
        Data::F32(d) => d.clone(),
        other => panic!("expected f32 result, got {other:?}"),
    }
}

fn out_s32(v: &Value) -> Vec<i32> {
    match &v.as_arr().expect("array result").data {
        Data::S32(d) => d.clone(),
        other => panic!("expected s32 result, got {other:?}"),
    }
}

fn artifacts() -> Option<PathBuf> {
    let p = memdyn::model::artifacts_dir(None);
    if p.join("index.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: no artifacts at {p:?} (run `make artifacts`)");
        None
    }
}

// ---------------------------------------------------------------------------
// per-op unit tests (no artifacts needed)
// ---------------------------------------------------------------------------

#[test]
fn elementwise_arithmetic_family() {
    let text = "HloModule arith
ENTRY main.1 {
  a.2 = f32[4] parameter(0)
  b.3 = f32[4] parameter(1)
  add.4 = f32[4] add(a.2, b.3)
  sub.5 = f32[4] subtract(a.2, b.3)
  mul.6 = f32[4] multiply(add.4, sub.5)
  div.7 = f32[4] divide(mul.6, b.3)
  max.8 = f32[4] maximum(div.7, a.2)
  min.9 = f32[4] minimum(max.8, b.3)
  ROOT rs.10 = f32[4] rsqrt(min.9)
}
";
    let a = [1.0f32, 2.0, 3.0, 4.0];
    let b = [4.0f32, 3.0, 2.0, 1.0];
    let got = out_f32(&run(
        text,
        &[vf32(&[4], a.to_vec()), vf32(&[4], b.to_vec())],
    ));
    for i in 0..4 {
        let want = 1.0
            / ((a[i] + b[i]) * (a[i] - b[i]) / b[i])
                .max(a[i])
                .min(b[i])
                .sqrt();
        assert!(
            (got[i] - want).abs() < 1e-6 || (got[i].is_nan() && want.is_nan()),
            "lane {i}: {} vs {want}",
            got[i]
        );
    }
}

#[test]
fn maximum_propagates_nan() {
    let text = "HloModule m
ENTRY main.1 {
  a.2 = f32[2] parameter(0)
  n.3 = f32[] constant(nan)
  b.4 = f32[2] broadcast(n.3), dimensions={}
  ROOT m.5 = f32[2] maximum(a.2, b.4)
}
";
    let got = out_f32(&run(text, &[vf32(&[2], vec![1.0, -1.0])]));
    assert!(got.iter().all(|v| v.is_nan()), "{got:?}");
}

#[test]
fn compare_select_and_logic_family() {
    let text = "HloModule c
ENTRY main.1 {
  a.2 = f32[4] parameter(0)
  b.3 = f32[4] parameter(1)
  lt.4 = pred[4] compare(a.2, b.3), direction=LT
  ge.5 = pred[4] compare(a.2, b.3), direction=GE
  or.6 = pred[4] or(lt.4, ge.5)
  and.7 = pred[4] and(lt.4, ge.5)
  sel.8 = f32[4] select(lt.4, a.2, b.3)
  z.9 = f32[] constant(0)
  zb.10 = f32[4] broadcast(z.9), dimensions={}
  o.11 = f32[] constant(1)
  ob.12 = f32[4] broadcast(o.11), dimensions={}
  both.13 = f32[4] select(and.7, ob.12, zb.10)
  either.14 = f32[4] select(or.6, ob.12, zb.10)
  s.15 = f32[4] add(sel.8, both.13)
  ROOT t.16 = f32[4] add(s.15, either.14)
}
";
    // sel = min(a,b); and = false; or = true (total order lanes)
    let got = out_f32(&run(
        text,
        &[
            vf32(&[4], vec![1.0, 5.0, 2.0, 2.0]),
            vf32(&[4], vec![3.0, 1.0, 2.0, 7.0]),
        ],
    ));
    assert_eq!(got, vec![2.0, 2.0, 3.0, 3.0]);
}

#[test]
fn s32_arithmetic_and_convert_family() {
    let text = "HloModule s
ENTRY main.1 {
  a.2 = s32[3] parameter(0)
  c.3 = s32[] constant(3)
  cb.4 = s32[3] broadcast(c.3), dimensions={}
  m.5 = s32[3] multiply(a.2, cb.4)
  f.6 = f32[3] convert(m.5)
  h.7 = f32[] constant(0.5)
  hb.8 = f32[3] broadcast(h.7), dimensions={}
  g.9 = f32[3] multiply(f.6, hb.8)
  ROOT r.10 = s32[3] convert(g.9)
}
";
    // x*3*0.5 truncated toward zero: 1->1, -3->-4.5->-4, 5->7.5->7
    let got = out_s32(&run(text, &[vs32(&[3], vec![1, -3, 5])]));
    assert_eq!(got, vec![1, -4, 7]);
}

#[test]
fn broadcast_iota_reshape_transpose_family() {
    let text = "HloModule b
ENTRY main.1 {
  i.2 = s32[6] iota(), iota_dimension=0
  r.3 = s32[2,3] reshape(i.2)
  t.4 = s32[3,2] transpose(r.3), dimensions={1,0}
  row.5 = s32[2] parameter(0)
  b.6 = s32[3,2] broadcast(row.5), dimensions={1}
  ROOT s.7 = s32[3,2] add(t.4, b.6)
}
";
    // iota 0..6 as [[0,1,2],[3,4,5]]; transpose -> [[0,3],[1,4],[2,5]];
    // +[10,20] per row
    let got = out_s32(&run(text, &[vs32(&[2], vec![10, 20])]));
    assert_eq!(got, vec![10, 23, 11, 24, 12, 25]);
}

#[test]
fn slice_pad_concatenate_family() {
    let text = "HloModule s
ENTRY main.1 {
  x.2 = f32[2,4] parameter(0)
  s.3 = f32[2,2] slice(x.2), slice={[0:2], [0:4:2]}
  z.4 = f32[] constant(9)
  p.5 = f32[2,3] pad(s.3, z.4), padding=0_0x0_1
  ROOT c.6 = f32[2,5] concatenate(p.5, s.3), dimensions={1}
}
";
    let got = out_f32(&run(
        text,
        &[vf32(&[2, 4], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0])],
    ));
    // strided slice cols {0,2}: [[0,2],[4,6]]; pad col 9 on the right;
    // concat the slice again
    assert_eq!(
        got,
        vec![0.0, 2.0, 9.0, 0.0, 2.0, 4.0, 6.0, 9.0, 4.0, 6.0]
    );
}

#[test]
fn pad_interior_family() {
    let text = "HloModule p
ENTRY main.1 {
  x.2 = f32[3] parameter(0)
  z.3 = f32[] constant(0)
  ROOT p.4 = f32[6] pad(x.2, z.3), padding=1_0_1
}
";
    let got = out_f32(&run(text, &[vf32(&[3], vec![1.0, 2.0, 3.0])]));
    assert_eq!(got, vec![0.0, 1.0, 0.0, 2.0, 0.0, 3.0]);
}

#[test]
fn dynamic_slice_and_update_family() {
    let text = "HloModule d
ENTRY main.1 {
  x.2 = f32[2,4] parameter(0)
  u.3 = f32[2,2] parameter(1)
  zero.4 = s32[] constant(0)
  two.5 = s32[] constant(2)
  upd.6 = f32[2,4] dynamic-update-slice(x.2, u.3, zero.4, two.5)
  big.7 = s32[] constant(99)
  ROOT ds.8 = f32[2,2] dynamic-slice(upd.6, zero.4, big.7), dynamic_slice_sizes={2,2}
}
";
    let got = out_f32(&run(
        text,
        &[
            vf32(&[2, 4], vec![0.0; 8]),
            vf32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]),
        ],
    ));
    // update written at col 2; the out-of-range start 99 clamps to col 2
    assert_eq!(got, vec![1.0, 2.0, 3.0, 4.0]);
}

#[test]
fn reduce_variadic_argmax_family() {
    // the artifacts' argmax idiom: a two-operand reduce over (value, index)
    let text = "HloModule a
region.1 {
  a0.2 = f32[] parameter(0)
  a1.3 = s32[] parameter(1)
  b0.4 = f32[] parameter(2)
  b1.5 = s32[] parameter(3)
  gt.6 = pred[] compare(a0.2, b0.4), direction=GT
  v.7 = f32[] select(gt.6, a0.2, b0.4)
  eq.8 = pred[] compare(a0.2, b0.4), direction=EQ
  lt.9 = pred[] compare(a1.3, b1.5), direction=LT
  tie.10 = pred[] and(eq.8, lt.9)
  keep.11 = pred[] or(gt.6, tie.10)
  i.12 = s32[] select(keep.11, a1.3, b1.5)
  ROOT t.13 = (f32[], s32[]) tuple(v.7, i.12)
}
ENTRY main.14 {
  x.15 = f32[2,4] parameter(0)
  iota.16 = s32[4] iota(), iota_dimension=0
  idx.17 = s32[2,4] broadcast(iota.16), dimensions={1}
  ninf.18 = f32[] constant(-inf)
  zero.19 = s32[] constant(0)
  r.20 = (f32[2], s32[2]) reduce(x.15, idx.17, ninf.18, zero.19), dimensions={1}, to_apply=region.1
  ROOT am.21 = s32[2] get-tuple-element(r.20), index=1
}
";
    let got = out_s32(&run(
        text,
        &[vf32(&[2, 4], vec![0.1, 0.9, 0.9, 0.2, 7.0, -1.0, 2.0, 7.0])],
    ));
    // ties resolve to the smallest index
    assert_eq!(got, vec![1, 0]);
}

#[test]
fn sort_two_operands_stable_family() {
    let text = "HloModule s
cmp.1 {
  a0.2 = f32[] parameter(0)
  b0.3 = f32[] parameter(1)
  a1.4 = s32[] parameter(2)
  b1.5 = s32[] parameter(3)
  ROOT lt.6 = pred[] compare(a0.2, b0.3), direction=LT
}
ENTRY main.7 {
  k.8 = f32[2,4] parameter(0)
  i.9 = s32[4] iota(), iota_dimension=0
  ib.10 = s32[2,4] broadcast(i.9), dimensions={1}
  s.11 = (f32[2,4], s32[2,4]) sort(k.8, ib.10), dimensions={1}, is_stable=true, to_apply=cmp.1
  ROOT p.12 = s32[2,4] get-tuple-element(s.11), index=1
}
";
    let got = out_s32(&run(
        text,
        &[vf32(&[2, 4], vec![3.0, 1.0, 2.0, 1.0, 0.0, 0.0, -1.0, 5.0])],
    ));
    // row 0: keys [3,1,2,1] -> indices [1,3,2,0] (equal keys keep order);
    // row 1: keys [0,0,-1,5] -> [2,0,1,3]
    assert_eq!(got, vec![1, 3, 2, 0, 2, 0, 1, 3]);
}

#[test]
fn gather_simple_family() {
    // artifact idiom gather.84: pick one element per index vector
    let text = "HloModule g
ENTRY main.1 {
  x.2 = s32[1,4] parameter(0)
  i.3 = s32[1] parameter(1)
  ROOT g.4 = s32[1,1] gather(x.2, i.3), offset_dims={0,1}, collapsed_slice_dims={}, start_index_map={1}, index_vector_dim=0, slice_sizes={1,1}, indices_are_sorted=true
}
";
    let got = out_s32(&run(
        text,
        &[vs32(&[1, 4], vec![10, 11, 12, 13]), vs32(&[1], vec![2])],
    ));
    assert_eq!(got, vec![12]);
}

#[test]
fn gather_with_batching_dims_family() {
    // artifact idiom gather.214: per-(batch,row) element lookup through
    // operand/start-indices batching dims
    let text = "HloModule g
ENTRY main.1 {
  x.2 = f32[1,2,3] parameter(0)
  i.3 = s32[1,2,2] parameter(1)
  ROOT g.4 = f32[1,2,2] gather(x.2, i.3), offset_dims={}, collapsed_slice_dims={2}, start_index_map={2}, operand_batching_dims={0,1}, start_indices_batching_dims={0,1}, index_vector_dim=3, slice_sizes={1,1,1}
}
";
    let got = out_f32(&run(
        text,
        &[
            vf32(&[1, 2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            vs32(&[1, 2, 2], vec![2, 0, 1, 1]),
        ],
    ));
    // row 0 picks cols {2,0}; row 1 picks cols {1,1}
    assert_eq!(got, vec![3.0, 1.0, 5.0, 5.0]);
}

#[test]
fn scatter_overwrite_family() {
    // artifact idiom scatter.104: mark visited indices (overwrite region)
    let text = "HloModule s
over.1 {
  old.2 = s32[] parameter(0)
  ROOT new.3 = s32[] parameter(1)
}
ENTRY main.4 {
  x.5 = s32[1,4] parameter(0)
  i.6 = s32[1] parameter(1)
  u.7 = s32[1] parameter(2)
  ROOT s.8 = s32[1,4] scatter(x.5, i.6, u.7), update_window_dims={0}, inserted_window_dims={1}, scatter_dims_to_operand_dims={1}, index_vector_dim=0, indices_are_sorted=true, unique_indices=true, to_apply=over.1
}
";
    let got = out_s32(&run(
        text,
        &[
            vs32(&[1, 4], vec![0, 0, 0, 0]),
            vs32(&[1], vec![2]),
            vs32(&[1], vec![7]),
        ],
    ));
    assert_eq!(got, vec![0, 0, 7, 0]);
}

#[test]
fn dot_matmul_family() {
    let text = "HloModule d
ENTRY main.1 {
  a.2 = f32[2,3] parameter(0)
  b.3 = f32[3,2] parameter(1)
  ROOT d.4 = f32[2,2] dot(a.2, b.3), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
";
    let got = out_f32(&run(
        text,
        &[
            vf32(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            vf32(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]),
        ],
    ));
    assert_eq!(got, vec![4.0, 5.0, 10.0, 11.0]);
}

/// Reference conv for the test below: NHWC x HWIO with groups.
#[allow(clippy::too_many_arguments)]
fn ref_conv(
    x: &[f32],
    w: &[f32],
    (n, h, wi, ci): (usize, usize, usize, usize),
    (kh, kw, cig, co): (usize, usize, usize, usize),
    stride: usize,
    pad: i64,
    (oh, ow): (usize, usize),
) -> Vec<f32> {
    let g = ci / cig;
    let cog = co / g;
    let mut out = vec![0f32; n * oh * ow * co];
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for oc in 0..co {
                    let grp = oc / cog;
                    let mut acc = 0f32;
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = (oy * stride + ky) as i64 - pad;
                            let ix = (ox * stride + kx) as i64 - pad;
                            if iy < 0 || ix < 0 || iy as usize >= h || ix as usize >= wi {
                                continue;
                            }
                            for c in 0..cig {
                                let xi = ((b * h + iy as usize) * wi + ix as usize) * ci
                                    + grp * cig
                                    + c;
                                let wx = ((ky * kw + kx) * cig + c) * co + oc;
                                acc += x[xi] * w[wx];
                            }
                        }
                    }
                    out[((b * oh + oy) * ow + ox) * co + oc] = acc;
                }
            }
        }
    }
    out
}

#[test]
fn convolution_depthwise_family() {
    let text = "HloModule c
ENTRY main.1 {
  x.2 = f32[1,3,3,2] parameter(0)
  w.3 = f32[3,3,1,2] parameter(1)
  ROOT c.4 = f32[1,3,3,2] convolution(x.2, w.3), window={size=3x3 pad=1_1x1_1}, dim_labels=b01f_01io->b01f, feature_group_count=2
}
";
    let x: Vec<f32> = (0..18).map(|i| (i as f32 * 0.37).sin()).collect();
    let w: Vec<f32> = (0..18).map(|i| ((i * 7 % 5) as f32 - 2.0) / 2.0).collect();
    let got = out_f32(&run(
        text,
        &[vf32(&[1, 3, 3, 2], x.clone()), vf32(&[3, 3, 1, 2], w.clone())],
    ));
    let want = ref_conv(&x, &w, (1, 3, 3, 2), (3, 3, 1, 2), 1, 1, (3, 3));
    for (a, b) in got.iter().zip(&want) {
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }
}

#[test]
fn convolution_strided_downsample_family() {
    let text = "HloModule c
ENTRY main.1 {
  x.2 = f32[1,4,4,2] parameter(0)
  w.3 = f32[1,1,2,3] parameter(1)
  ROOT c.4 = f32[1,2,2,3] convolution(x.2, w.3), window={size=1x1 stride=2x2}, dim_labels=b01f_01io->b01f
}
";
    let x: Vec<f32> = (0..32).map(|i| i as f32).collect();
    let w: Vec<f32> = (0..6).map(|i| (i as f32) - 2.5).collect();
    let got = out_f32(&run(
        text,
        &[vf32(&[1, 4, 4, 2], x.clone()), vf32(&[1, 1, 2, 3], w.clone())],
    ));
    let want = ref_conv(&x, &w, (1, 4, 4, 2), (1, 1, 2, 3), 2, 0, (2, 2));
    for (a, b) in got.iter().zip(&want) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
}

#[test]
fn constant_array_literals_family() {
    let text = "HloModule k
ENTRY main.1 {
  c.2 = s32[2,2] constant({ {1, -2}, {3, -4} })
  f.3 = f32[2,2] constant({ { /*i0=0*/ 0.5, 1.5 }, { 2.5, 1e+01 } })
  g.4 = f32[2,2] convert(c.2)
  ROOT m.5 = f32[2,2] multiply(g.4, f.3)
}
";
    let got = out_f32(&run(text, &[]));
    assert_eq!(got, vec![0.5, -3.0, 7.5, -40.0]);
}

// ---------------------------------------------------------------------------
// aliasing regressions: the in-place dynamic-update-slice discipline
// ---------------------------------------------------------------------------

/// A `while` loop whose carried `f32[8]` buffer is dead outside the loop:
/// after the first iteration the buffer's `Arc` is uniquely held, so the
/// evaluator MUST update it in place (no per-iteration copy).
const WHILE_DUS_TEXT: &str = "HloModule w
cond.1 {
  p.2 = (f32[8], s32[]) parameter(0)
  i.3 = s32[] get-tuple-element(p.2), index=1
  c.4 = s32[] constant(4)
  ROOT lt.5 = pred[] compare(i.3, c.4), direction=LT
}
body.6 {
  p.7 = (f32[8], s32[]) parameter(0)
  buf.8 = f32[8] get-tuple-element(p.7), index=0
  i.9 = s32[] get-tuple-element(p.7), index=1
  one.10 = f32[1] constant({1})
  upd.11 = f32[8] dynamic-update-slice(buf.8, one.10, i.9)
  c.12 = s32[] constant(1)
  ni.13 = s32[] add(i.9, c.12)
  ROOT t.14 = (f32[8], s32[]) tuple(upd.11, ni.13)
}
ENTRY main.15 {
  z.16 = f32[8] parameter(0)
  c.17 = s32[] constant(0)
  t.18 = (f32[8], s32[]) tuple(z.16, c.17)
  w.19 = (f32[8], s32[]) while(t.18), condition=cond.1, body=body.6
  ROOT g.20 = f32[8] get-tuple-element(w.19), index=0
}
";

#[test]
fn while_loop_dus_reuses_uniquely_held_buffer_in_place() {
    // counters are process-global and monotone: concurrent tests can only
    // add, so the deltas below are lower bounds on *this* run's behavior
    let in_place_before = memdyn::hlo::eval::dus_in_place_count();
    let got = out_f32(&run(WHILE_DUS_TEXT, &[vf32(&[8], vec![0.0; 8])]));
    assert_eq!(got, vec![1.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0]);
    let in_place_delta = memdyn::hlo::eval::dus_in_place_count() - in_place_before;
    // 4 iterations: iteration 1 must copy (the caller still holds the
    // input buffer), iterations 2-4 must reuse
    assert!(
        in_place_delta >= 3,
        "expected >= 3 in-place dynamic-update-slice executions, saw {in_place_delta}"
    );
}

#[test]
fn while_loop_dus_must_not_mutate_buffer_live_after_the_loop() {
    // same loop shape, but the loop-carried operand is ALSO consumed
    // after the loop: the first write may never be applied in place to
    // the shared buffer, or `z + loop_result` silently corrupts
    let text = "HloModule alias
cond.1 {
  p.2 = (f32[4], s32[]) parameter(0)
  i.3 = s32[] get-tuple-element(p.2), index=1
  c.4 = s32[] constant(4)
  ROOT lt.5 = pred[] compare(i.3, c.4), direction=LT
}
body.6 {
  p.7 = (f32[4], s32[]) parameter(0)
  buf.8 = f32[4] get-tuple-element(p.7), index=0
  i.9 = s32[] get-tuple-element(p.7), index=1
  nine.10 = f32[1] constant({9})
  upd.11 = f32[4] dynamic-update-slice(buf.8, nine.10, i.9)
  c.12 = s32[] constant(1)
  ni.13 = s32[] add(i.9, c.12)
  ROOT t.14 = (f32[4], s32[]) tuple(upd.11, ni.13)
}
ENTRY main.15 {
  z.16 = f32[4] parameter(0)
  c.17 = s32[] constant(0)
  t.18 = (f32[4], s32[]) tuple(z.16, c.17)
  w.19 = (f32[4], s32[]) while(t.18), condition=cond.1, body=body.6
  wb.20 = f32[4] get-tuple-element(w.19), index=0
  ROOT s.21 = f32[4] add(wb.20, z.16)
}
";
    let got = out_f32(&run(text, &[vf32(&[4], vec![1.0, 2.0, 3.0, 4.0])]));
    // loop overwrites every lane with 9; z must still be [1,2,3,4]
    assert_eq!(got, vec![10.0, 11.0, 12.0, 13.0]);
}

#[test]
fn straight_line_dus_reuses_fresh_buffer_and_copies_shared_one() {
    // `a = x + x` is freshly allocated and dies at the update: MUST reuse.
    // `x` itself is a parameter the caller still holds: updating it must
    // leave the original readable (checked through the second output).
    let text = "HloModule d
ENTRY main.1 {
  x.2 = f32[6] parameter(0)
  u.3 = f32[2] parameter(1)
  s.4 = s32[] constant(1)
  a.5 = f32[6] add(x.2, x.2)
  fresh.6 = f32[6] dynamic-update-slice(a.5, u.3, s.4)
  shared.7 = f32[6] dynamic-update-slice(x.2, u.3, s.4)
  back.8 = f32[6] add(shared.7, x.2)
  ROOT t.9 = (f32[6], f32[6]) tuple(fresh.6, back.8)
}
";
    let in_place_before = memdyn::hlo::eval::dus_in_place_count();
    let copied_before = memdyn::hlo::eval::dus_copied_count();
    let out = run(
        text,
        &[
            vf32(&[6], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            vf32(&[2], vec![40.0, 50.0]),
        ],
    );
    let parts = out.as_tuple().expect("tuple result");
    let fresh = match &parts[0].as_arr().unwrap().data {
        Data::F32(d) => d.clone(),
        other => panic!("expected f32, got {other:?}"),
    };
    let back = match &parts[1].as_arr().unwrap().data {
        Data::F32(d) => d.clone(),
        other => panic!("expected f32, got {other:?}"),
    };
    assert_eq!(fresh, vec![2.0, 40.0, 50.0, 8.0, 10.0, 12.0]);
    // shared.7 = [1,40,50,4,5,6]; x.2 unchanged when the add reads it
    assert_eq!(back, vec![2.0, 42.0, 53.0, 8.0, 10.0, 12.0]);
    assert!(
        memdyn::hlo::eval::dus_in_place_count() - in_place_before >= 1,
        "uniquely held operand must be updated in place"
    );
    assert!(
        memdyn::hlo::eval::dus_copied_count() - copied_before >= 1,
        "operand with live references must be copied"
    );
}

// ---------------------------------------------------------------------------
// row-parallel dot/convolution: bit-identical at every fan-out width
// ---------------------------------------------------------------------------

#[test]
fn dot_row_parallelism_is_bit_identical_across_fanout() {
    // 32x64 @ 64x64 = 131072 MACs, above the fan-out threshold
    let text = "HloModule d
ENTRY main.1 {
  a.2 = f32[32,64] parameter(0)
  b.3 = f32[64,64] parameter(1)
  ROOT d.4 = f32[32,64] dot(a.2, b.3), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
";
    let a: Vec<f32> = (0..32 * 64).map(|i| ((i % 13) as f32 - 6.0) * 0.17).collect();
    let b: Vec<f32> = (0..64 * 64).map(|i| ((i % 7) as f32 - 3.0) * 0.29).collect();
    let mut outs = Vec::new();
    for threads in [1usize, 4] {
        memdyn::hlo::eval::set_linear_fanout(threads);
        outs.push(out_f32(&run(
            text,
            &[vf32(&[32, 64], a.clone()), vf32(&[64, 64], b.clone())],
        )));
    }
    memdyn::hlo::eval::set_linear_fanout(0);
    assert_eq!(outs[0], outs[1], "dot rows diverged between fanout 1 and 4");
}

#[test]
fn convolution_row_parallelism_is_bit_identical_across_fanout() {
    // 8 output rows x 8x16x(3*3*8) = 73728 MACs, above the threshold
    let text = "HloModule c
ENTRY main.1 {
  x.2 = f32[1,8,8,8] parameter(0)
  w.3 = f32[3,3,8,16] parameter(1)
  ROOT c.4 = f32[1,8,8,16] convolution(x.2, w.3), window={size=3x3 pad=1_1x1_1}, dim_labels=b01f_01io->b01f
}
";
    let x: Vec<f32> = (0..8 * 8 * 8).map(|i| (i as f32 * 0.13).sin()).collect();
    let w: Vec<f32> = (0..3 * 3 * 8 * 16).map(|i| ((i % 11) as f32 - 5.0) * 0.07).collect();
    let mut outs = Vec::new();
    for threads in [1usize, 4] {
        memdyn::hlo::eval::set_linear_fanout(threads);
        outs.push(out_f32(&run(
            text,
            &[vf32(&[1, 8, 8, 8], x.clone()), vf32(&[3, 3, 8, 16], w.clone())],
        )));
    }
    memdyn::hlo::eval::set_linear_fanout(0);
    assert_eq!(
        outs[0], outs[1],
        "convolution rows diverged between fanout 1 and 4"
    );
}

// ---------------------------------------------------------------------------
// bit-packed ternary dot dispatch (cim::packed via the load-time scan)
// ---------------------------------------------------------------------------

/// Serializes the tests that toggle `cim::packed::set_enabled` or assert
/// on the `dot_packed_count`/`dot_dense_count` dispatch counters, so a
/// concurrently running toggle can't flip another test's kernel choice
/// mid-assert.  Survives poisoning (counter asserts are monotone).
static PACKED_GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn packed_gate() -> std::sync::MutexGuard<'static, ()> {
    PACKED_GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Module with a `(m,k) x (k,n)` dot over an inline ternary constant.
fn ternary_dot_module(m: usize, k: usize, n: usize, w: &[i8]) -> String {
    let rows: Vec<String> = (0..k)
        .map(|kk| {
            let row: Vec<String> = (0..n).map(|j| w[kk * n + j].to_string()).collect();
            format!("{{ {} }}", row.join(", "))
        })
        .collect();
    format!(
        "HloModule p\nENTRY main.1 {{\n  \
         x.2 = f32[{m},{k}] parameter(0)\n  \
         w.3 = f32[{k},{n}] constant({{ {} }})\n  \
         ROOT d.4 = f32[{m},{n}] dot(x.2, w.3), \
         lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n}}\n",
        rows.join(", ")
    )
}

fn ternary_weights(k: usize, n: usize, seed: u64) -> Vec<i8> {
    let mut rng = Pcg64::new(seed);
    (0..k * n).map(|_| [-1i8, 0, 1][rng.below(3)]).collect()
}

fn dense_dot(x: &[f32], w: &[i8], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut y = vec![0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            for j in 0..n {
                y[i * n + j] += x[i * k + kk] * w[kk * n + j] as f32;
            }
        }
    }
    y
}

#[test]
fn dot_ternary_constant_dispatches_packed_and_matches_dense_exactly() {
    let _g = packed_gate();
    let (m, k, n) = (4usize, 70usize, 6usize); // k = one word + 6-bit tail
    let w = ternary_weights(k, n, 51);
    let text = ternary_dot_module(m, k, n, &w);
    let x: Vec<f32> = (0..m * k).map(|i| (i as i64 % 17 - 8) as f32).collect();
    let want = dense_dot(&x, &w, m, k, n);

    let packed_before = memdyn::hlo::eval::dot_packed_count();
    let got = out_f32(&run(&text, &[vf32(&[m, k], x.clone())]));
    assert_eq!(got, want, "packed dot != dense oracle on integer inputs");
    assert!(
        memdyn::hlo::eval::dot_packed_count() - packed_before >= 1,
        "ternary-constant dot must take the packed kernel"
    );

    // disabled: same module re-routes to the dense kernel, same bits
    memdyn::cim::packed::set_enabled(false);
    let dense_before = memdyn::hlo::eval::dot_dense_count();
    let dense = out_f32(&run(&text, &[vf32(&[m, k], x)]));
    memdyn::cim::packed::set_enabled(true);
    assert_eq!(dense, want, "dense fallback diverged");
    assert!(
        memdyn::hlo::eval::dot_dense_count() - dense_before >= 1,
        "disabled packing must fall back to the dense kernel"
    );
}

#[test]
fn dot_packed_dispatch_is_fanout_invariant() {
    // 32x70 @ 70x40 = 89600 MACs, above the fan-out threshold, so the
    // rows really chunk across the pool at fanout 4; the kernel choice
    // is made before chunking, so every width must (a) still dispatch
    // packed and (b) produce bit-identical output
    let _g = packed_gate();
    let (m, k, n) = (32usize, 70usize, 40usize);
    let w = ternary_weights(k, n, 52);
    let text = ternary_dot_module(m, k, n, &w);
    let x: Vec<f32> = (0..m * k).map(|i| (i as i64 % 23 - 11) as f32).collect();
    let want = dense_dot(&x, &w, m, k, n);
    let mut outs = Vec::new();
    for threads in [1usize, 4] {
        memdyn::hlo::eval::set_linear_fanout(threads);
        let before = memdyn::hlo::eval::dot_packed_count();
        outs.push(out_f32(&run(&text, &[vf32(&[m, k], x.clone())])));
        assert!(
            memdyn::hlo::eval::dot_packed_count() - before >= 1,
            "fanout {threads} changed the kernel a dot takes"
        );
    }
    memdyn::hlo::eval::set_linear_fanout(0);
    assert_eq!(outs[0], want, "packed dot != dense oracle");
    assert_eq!(outs[0], outs[1], "packed dot diverged between fanout 1 and 4");
}

#[test]
fn dot_packed_dispatch_is_bucket_invariant_b1_vs_b8() {
    // the same ternary constant traced at bucket sizes 1 and 8 (separate
    // modules, as the artifact buckets are): both must dispatch packed,
    // and the shared row must come out bit-identical
    let _g = packed_gate();
    let (k, n) = (70usize, 12usize);
    let w = ternary_weights(k, n, 53);
    let t1 = ternary_dot_module(1, k, n, &w);
    let t8 = ternary_dot_module(8, k, n, &w);
    let x8: Vec<f32> = (0..8 * k).map(|i| (i as i64 % 19 - 9) as f32).collect();
    let before = memdyn::hlo::eval::dot_packed_count();
    let y1 = out_f32(&run(&t1, &[vf32(&[1, k], x8[..k].to_vec())]));
    let y8 = out_f32(&run(&t8, &[vf32(&[8, k], x8.clone())]));
    assert!(
        memdyn::hlo::eval::dot_packed_count() - before >= 2,
        "both bucket modules must dispatch the packed kernel"
    );
    assert_eq!(y1[..], y8[..n], "row 0 diverged between b1 and b8");
    assert_eq!(y8, dense_dot(&x8, &w, 8, k, n), "b8 != dense oracle");
}

// ---------------------------------------------------------------------------
// compiled step programs (hlo::plan): planned execution vs tree-walk oracle
// ---------------------------------------------------------------------------

#[test]
fn planned_execution_matches_tree_walk_oracle_bit_for_bit() {
    // the compiled step program must reproduce the tree walk exactly on
    // the aliasing-heavy shape (while + dynamic-update-slice), reached
    // three ways: planned (default), tree via the toggle, and the
    // explicit run_entry_tree oracle
    let _g = packed_gate(); // serializes all global-toggle tests
    let m = parse(WHILE_DUS_TEXT).expect("module should parse");
    let interp = Interpreter::new(m).expect("module should verify");
    let args = [vf32(&[8], vec![0.0; 8])];
    let want = vec![1.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0];

    let runs_before = memdyn::hlo::plan::run_count();
    memdyn::hlo::plan::set_enabled(true);
    let planned = interp.run_entry(&args).unwrap();
    assert!(
        memdyn::hlo::plan::run_count() - runs_before >= 1,
        "enabled plan must execute through the step program"
    );
    memdyn::hlo::plan::set_enabled(false);
    let tree = interp.run_entry(&args).unwrap();
    memdyn::hlo::plan::set_enabled(true);
    let oracle = interp.run_entry_tree(&args).unwrap();

    assert_eq!(out_f32(&planned), want);
    assert_eq!(out_f32(&planned), out_f32(&oracle), "planned != oracle");
    assert_eq!(out_f32(&tree), out_f32(&oracle), "toggle-off != oracle");
}

#[test]
fn planned_packed_dot_is_exact_and_fanout_invariant() {
    // acceptance gate: the bytecode path stays exact on the packed
    // integer route and bit-identical across row fan-out {1, 4}, in both
    // plan states
    let _g = packed_gate();
    let (m, k, n) = (4usize, 70usize, 6usize);
    let w = ternary_weights(k, n, 54);
    let text = ternary_dot_module(m, k, n, &w);
    let x: Vec<f32> = (0..m * k).map(|i| (i as i64 % 17 - 8) as f32).collect();
    let want = dense_dot(&x, &w, m, k, n);

    for planned in [true, false] {
        memdyn::hlo::plan::set_enabled(planned);
        let mut outs = Vec::new();
        for threads in [1usize, 4] {
            memdyn::hlo::eval::set_linear_fanout(threads);
            let before = memdyn::hlo::eval::dot_packed_count();
            outs.push(out_f32(&run(&text, &[vf32(&[m, k], x.clone())])));
            assert!(
                memdyn::hlo::eval::dot_packed_count() - before >= 1,
                "plan={planned}, fanout {threads}: dot must stay packed"
            );
        }
        memdyn::hlo::eval::set_linear_fanout(0);
        assert_eq!(outs[0], want, "plan={planned}: packed dot != dense oracle");
        assert_eq!(
            outs[0], outs[1],
            "plan={planned}: diverged between fanout 1 and 4"
        );
    }
    memdyn::hlo::plan::set_enabled(true);
}

#[test]
fn planned_dus_discipline_matches_tree_walk_counters() {
    // the plan's static InPlace/Fresh tags must drive the same counter
    // deltas the runtime check produces: >= 3 in-place updates for the
    // 4-iteration loop (iteration 1 copies, the caller still holds the
    // input buffer)
    let _g = packed_gate();
    memdyn::hlo::plan::set_enabled(true);
    let in_place_before = memdyn::hlo::eval::dus_in_place_count();
    let got = out_f32(&run(WHILE_DUS_TEXT, &[vf32(&[8], vec![0.0; 8])]));
    assert_eq!(got, vec![1.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0]);
    assert!(
        memdyn::hlo::eval::dus_in_place_count() - in_place_before >= 3,
        "planned path lost the in-place dynamic-update-slice discipline"
    );
}

// ---------------------------------------------------------------------------
// artifact census + end-to-end conformance (need `make artifacts`)
// ---------------------------------------------------------------------------

#[test]
fn artifact_census_every_opcode_supported_and_used() {
    let Some(dir) = artifacts() else { return };
    let mut used: BTreeSet<String> = BTreeSet::new();
    let mut files = 0usize;
    for sub in ["resnet", "pointnet", "kernels"] {
        let Ok(entries) = std::fs::read_dir(dir.join(sub)) else {
            continue;
        };
        for e in entries.flatten() {
            let p = e.path();
            if !p.to_string_lossy().ends_with(".hlo.txt") {
                continue;
            }
            let text = std::fs::read_to_string(&p).unwrap();
            let module = parse(&text)
                .unwrap_or_else(|err| panic!("{p:?} failed to parse: {err:#}"));
            for c in &module.comps {
                for ins in &c.instrs {
                    used.insert(ins.op.name().to_string());
                }
            }
            files += 1;
        }
    }
    assert!(files >= 40, "only {files} HLO artifacts found");
    let supported: BTreeSet<String> =
        SUPPORTED_OPS.iter().map(|s| s.to_string()).collect();
    assert_eq!(
        used, supported,
        "artifact opcode census diverged from SUPPORTED_OPS"
    );
}

#[test]
fn cim_smoke_kernel_matches_plain_matmul() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(&dir.join("kernels/cim_smoke.hlo.txt")).unwrap();
    let b = memdyn::util::bin_io::Bundle::load(&dir.join("kernels/cim_smoke")).unwrap();
    let (wshape, w) = b.f32("w").unwrap();
    let (k, n) = (wshape[0], wshape[1]);
    let m = 16usize;
    let x: Vec<f32> = (0..m * k).map(|i| ((i % 7) as f32 - 3.0) / 3.0).collect();
    let out = exe
        .run(&[memdyn::runtime::TensorIn {
            data: &x,
            shape: &[m, k],
        }])
        .unwrap();
    let want = memdyn::nn::ops::matmul(&x, &w, m, k, n);
    assert_eq!(out.len(), 1);
    for (a, b) in out[0].iter().zip(&want) {
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
}

/// |a-b| <= tol * max(1, |b|): "within 1e-4" in the relative sense, with
/// an absolute floor for near-zero entries.
fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * b.abs().max(1.0)
}

#[test]
fn xla_resnet_parity_with_native_digital_within_1e4() {
    let Some(dir) = artifacts() else { return };
    let bundle = ModelBundle::load(&dir, "resnet").unwrap();
    let data = DatasetBundle::load(&dir, "mnist").unwrap();
    let rt = Runtime::cpu().unwrap();
    let xla = XlaResNetModel::load(&rt, &bundle).unwrap();
    let mut rng = Pcg64::new(1);
    let native =
        NativeResNet::build(&bundle, WeightSource::Ternary, &NoiseSpec::Digital, &mut rng)
            .unwrap();

    let batch = 3usize;
    let input = &data.x_test[..batch * data.sample_len];
    let feat = memdyn::nn::resnet::image_feature(input, batch, 28).unwrap();
    let keys: Vec<StreamKey> = (0..batch as u64).map(|i| StreamKey::root(1).child(i)).collect();
    let (nat_logits, nat_svs) = native.forward(&feat, &keys);

    let mut state = xla.init_seq(input, batch, 0).unwrap();
    let mut xla_svs = Vec::new();
    for i in 0..xla.n_blocks() {
        xla_svs.push(xla.step(i, &mut state).unwrap());
    }
    let xla_logits = xla.finish(&state).unwrap();

    for (i, (nsv, xsv)) in nat_svs.iter().zip(&xla_svs).enumerate() {
        assert_eq!(nsv.len(), xsv.len(), "sv length at block {i}");
        for (a, b) in xsv.iter().zip(nsv) {
            assert!(close(*a, *b, 1e-4), "block {i}: xla {a} vs native {b}");
        }
    }
    assert_eq!(xla_logits.len(), nat_logits.len());
    for (a, b) in xla_logits.iter().zip(&nat_logits) {
        assert!(close(*a, *b, 1e-4), "logits: xla {a} vs native {b}");
    }
}

#[test]
fn xla_resnet_parity_holds_under_row_parallel_kernels() {
    // the 1e-4 xla-vs-native gate re-run with the interpreter's
    // dot/convolution row fan-out pinned to 1 and 4: outputs must stay
    // within tolerance of the native forward at both widths AND be
    // bit-identical to each other
    let Some(dir) = artifacts() else { return };
    let bundle = ModelBundle::load(&dir, "resnet").unwrap();
    let data = DatasetBundle::load(&dir, "mnist").unwrap();
    let rt = Runtime::cpu().unwrap();
    let xla = XlaResNetModel::load(&rt, &bundle).unwrap();
    let mut rng = Pcg64::new(1);
    let native =
        NativeResNet::build(&bundle, WeightSource::Ternary, &NoiseSpec::Digital, &mut rng)
            .unwrap();

    let batch = 2usize;
    let input = &data.x_test[..batch * data.sample_len];
    let feat = memdyn::nn::resnet::image_feature(input, batch, 28).unwrap();
    let keys: Vec<StreamKey> =
        (0..batch as u64).map(|i| StreamKey::root(1).child(i)).collect();
    let (nat_logits, _) = native.forward(&feat, &keys);

    let mut per_fanout: Vec<Vec<f32>> = Vec::new();
    for threads in [1usize, 4] {
        memdyn::hlo::eval::set_linear_fanout(threads);
        let mut state = xla.init_seq(input, batch, 0).unwrap();
        for i in 0..xla.n_blocks() {
            let _ = xla.step(i, &mut state).unwrap();
        }
        let logits = xla.finish(&state).unwrap();
        for (a, b) in logits.iter().zip(&nat_logits) {
            assert!(
                close(*a, *b, 1e-4),
                "fanout {threads}: xla {a} vs native {b}"
            );
        }
        per_fanout.push(logits);
    }
    memdyn::hlo::eval::set_linear_fanout(0);
    assert_eq!(
        per_fanout[0], per_fanout[1],
        "interpreter logits diverged between fanout 1 and 4"
    );
}

#[test]
fn xla_resnet_parity_holds_with_packing_toggled() {
    // the 1e-4 xla-vs-native gate re-run with the bit-packed ternary
    // kernel explicitly on and explicitly off: tolerance must hold in
    // both states (the packed path reorders f32 accumulation, so the
    // two runs need not be bit-identical — only both within the gate)
    let Some(dir) = artifacts() else { return };
    let _g = packed_gate();
    let bundle = ModelBundle::load(&dir, "resnet").unwrap();
    let data = DatasetBundle::load(&dir, "mnist").unwrap();
    let rt = Runtime::cpu().unwrap();
    let xla = XlaResNetModel::load(&rt, &bundle).unwrap();
    let mut rng = Pcg64::new(1);
    let native =
        NativeResNet::build(&bundle, WeightSource::Ternary, &NoiseSpec::Digital, &mut rng)
            .unwrap();

    let batch = 2usize;
    let input = &data.x_test[..batch * data.sample_len];
    let feat = memdyn::nn::resnet::image_feature(input, batch, 28).unwrap();
    let keys: Vec<StreamKey> =
        (0..batch as u64).map(|i| StreamKey::root(1).child(i)).collect();
    let (nat_logits, _) = native.forward(&feat, &keys);

    for on in [true, false] {
        memdyn::cim::packed::set_enabled(on);
        let mut state = xla.init_seq(input, batch, 0).unwrap();
        for i in 0..xla.n_blocks() {
            let _ = xla.step(i, &mut state).unwrap();
        }
        let logits = xla.finish(&state).unwrap();
        for (a, b) in logits.iter().zip(&nat_logits) {
            assert!(
                close(*a, *b, 1e-4),
                "packing {on}: xla {a} vs native {b}"
            );
        }
    }
    memdyn::cim::packed::set_enabled(true);
}

#[test]
fn xla_resnet_parity_holds_with_plan_toggled() {
    // the compiled step program on the shipped artifacts: logits must be
    // bit-identical between the planned path and the tree-walk oracle
    // (the two share every kernel; only the decision source differs) and
    // within the 1e-4 gate of the native digital forward in both states
    let Some(dir) = artifacts() else { return };
    let _g = packed_gate();
    let bundle = ModelBundle::load(&dir, "resnet").unwrap();
    let data = DatasetBundle::load(&dir, "mnist").unwrap();
    let rt = Runtime::cpu().unwrap();
    let xla = XlaResNetModel::load(&rt, &bundle).unwrap();
    let mut rng = Pcg64::new(1);
    let native =
        NativeResNet::build(&bundle, WeightSource::Ternary, &NoiseSpec::Digital, &mut rng)
            .unwrap();

    let batch = 2usize;
    let input = &data.x_test[..batch * data.sample_len];
    let feat = memdyn::nn::resnet::image_feature(input, batch, 28).unwrap();
    let keys: Vec<StreamKey> =
        (0..batch as u64).map(|i| StreamKey::root(1).child(i)).collect();
    let (nat_logits, _) = native.forward(&feat, &keys);

    let mut per_state: Vec<Vec<f32>> = Vec::new();
    for planned in [true, false] {
        memdyn::hlo::plan::set_enabled(planned);
        let mut state = xla.init_seq(input, batch, 0).unwrap();
        for i in 0..xla.n_blocks() {
            let _ = xla.step(i, &mut state).unwrap();
        }
        let logits = xla.finish(&state).unwrap();
        for (a, b) in logits.iter().zip(&nat_logits) {
            assert!(close(*a, *b, 1e-4), "plan={planned}: xla {a} vs native {b}");
        }
        per_state.push(logits);
    }
    memdyn::hlo::plan::set_enabled(true);
    assert_eq!(
        per_state[0], per_state[1],
        "planned artifacts diverged from the tree walk"
    );
}

#[test]
fn xla_pointnet_bucket_padding_consistent_within_1e4() {
    let Some(dir) = artifacts() else { return };
    let bundle = ModelBundle::load(&dir, "pointnet").unwrap();
    let data = DatasetBundle::load(&dir, "modelnet").unwrap();
    let rt = Runtime::cpu().unwrap();
    let xla = XlaPointNetModel::load(&rt, &bundle).unwrap();
    let sl = data.sample_len;
    // the same cloud must produce the same search vectors at batch 1
    // (b1 executable) and batch 3 (padded into the b4 executable)
    let mut s1 = xla.init_seq(&data.x_test[..sl], 1, 0).unwrap();
    let mut s3 = xla.init_seq(&data.x_test[..3 * sl], 3, 0).unwrap();
    for i in 0..2 {
        let sv1 = xla.step(i, &mut s1).unwrap();
        let sv3 = xla.step(i, &mut s3).unwrap();
        for (a, b) in sv1.iter().zip(&sv3[..sv1.len()]) {
            assert!(close(*a, *b, 1e-4), "SA {i}: b1 {a} vs b4 {b}");
        }
    }
}
