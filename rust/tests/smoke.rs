//! Public-API smoke tests: exercise the crate surface end to end with no
//! artifacts on disk — these must stay green on a fresh checkout.

use std::sync::mpsc::sync_channel;
use std::time::{Duration, Instant};

use memdyn::coordinator::dynmodel::DynModel;
use memdyn::coordinator::engine::Outcome;
use memdyn::coordinator::server::{collect_batch, Request, Response};
use memdyn::coordinator::{Engine, ExitMemory, ServerConfig};

/// `ServerConfig::default()` drives `collect_batch`, and a queued request
/// round-trips through the public `Request`/`Response` types.
#[test]
fn server_config_default_collect_batch_roundtrip() {
    let cfg = ServerConfig::default();
    assert!(cfg.max_batch >= 1);
    assert!(cfg.queue_cap >= 1);
    assert!(cfg.max_wait > Duration::ZERO);
    // admission-control defaults: no deadline, continuous batching on
    assert!(cfg.deadline.is_none());
    assert!(cfg.backfill);

    let (tx, rx) = sync_channel::<Request>(cfg.queue_cap);
    let (resp_tx, resp_rx) = sync_channel::<Response>(1);
    tx.send(Request {
        input: vec![0.5, 0.25],
        id: 0,
        submitted: Instant::now(),
        resp: resp_tx,
    })
    .unwrap();

    let batch = collect_batch(&rx, cfg.max_batch, cfg.max_wait).expect("open queue");
    assert_eq!(batch.len(), 1);
    assert_eq!(batch[0].input, vec![0.5, 0.25]);

    // complete the round trip the way the worker does
    let outcome = Outcome {
        class: 1,
        exit: 0,
        exited_early: true,
        similarity: 0.93,
    };
    batch[0]
        .resp
        .send(Response {
            outcome: Ok(outcome),
            latency: batch[0].submitted.elapsed(),
        })
        .unwrap();
    let r = resp_rx.recv().unwrap();
    let got = r.outcome.expect("worker sent a success");
    assert_eq!(got.class, 1);
    assert!(got.exited_early);

    // closing the queue ends the batching loop
    drop(tx);
    assert!(collect_batch(&rx, cfg.max_batch, cfg.max_wait).is_none());
}

/// Minimal user-defined backbone: proves the `DynModel` + `ExitMemory` +
/// `Engine` public surface composes outside the crate.
struct Identity {
    blocks: usize,
    classes: usize,
}

impl DynModel for Identity {
    type State = Vec<Vec<f32>>;

    fn n_blocks(&self) -> usize {
        self.blocks
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn init(
        &self,
        input: &[f32],
        batch: usize,
        _reqs: &[u64],
    ) -> anyhow::Result<Self::State> {
        let w = input.len() / batch;
        Ok((0..batch)
            .map(|i| input[i * w..(i + 1) * w].to_vec())
            .collect())
    }

    fn step(&self, _i: usize, state: &mut Self::State) -> anyhow::Result<Vec<f32>> {
        Ok(state.concat())
    }

    fn batch_of(&self, state: &Self::State) -> usize {
        state.len()
    }

    fn select(&self, state: &Self::State, keep: &[usize]) -> Self::State {
        keep.iter().map(|&r| state[r].clone()).collect()
    }

    fn finish(&self, state: &Self::State) -> anyhow::Result<Vec<f32>> {
        Ok(state
            .iter()
            .flat_map(|r| r[..self.classes].to_vec())
            .collect())
    }
}

#[test]
fn engine_public_api_composes_with_custom_model() {
    // two classes, axis-aligned centers at both exits
    let bank = (vec![1.0f32, 0.0, 0.0, 1.0], 2usize, 2usize);
    let engine = Engine::new(
        Identity {
            blocks: 2,
            classes: 2,
        },
        ExitMemory::exact(vec![bank.clone(), bank]),
        vec![0.95, 0.95],
    );
    // a confident class-1 sample exits at block 0; an ambiguous one reaches
    // the head and is classified by argmax
    let out = engine
        .infer_batch(&[0.0, 1.0, 0.6, 0.55], 2)
        .expect("inference");
    assert_eq!(out[0].class, 1);
    assert!(out[0].exited_early);
    assert_eq!(out[0].exit, 0);
    assert_eq!(out[1].class, 0);
    assert!(!out[1].exited_early);
    assert_eq!(out[1].exit, 1);
}
