//! Property-based tests (mini driver in `memdyn::util::proptest`) over the
//! simulator's core invariants — randomized shapes/values, deterministic
//! seeds, failure reports with reproduction seeds.

use memdyn::budget::BudgetModel;
use memdyn::cam::CamBank;
use memdyn::cim::packed::{ActivationPlanes, PackedTernary};
use memdyn::cim::CimMatrix;
use memdyn::crossbar::ConverterConfig;
use memdyn::device::DeviceConfig;
use memdyn::nn::ops;
use memdyn::opt::ExitTrace;
use memdyn::util::json::Json;
use memdyn::util::pool;
use memdyn::util::proptest::forall;
use memdyn::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// persistent worker pool: chunking is a partition, pooled == sequential
// ---------------------------------------------------------------------------

#[test]
fn prop_chunk_ranges_is_a_partition() {
    // random (n, threads) including n = 0, threads = 1, threads > n
    forall(
        21,
        80,
        |g| (g.dim0(64), g.threads(12)),
        |&(n, threads)| {
            let rs = pool::chunk_ranges(n, threads);
            if rs.len() > threads.max(1) {
                return Err(format!("{} chunks for {threads} threads", rs.len()));
            }
            let mut at = 0usize;
            for r in &rs {
                if r.start != at {
                    return Err(format!("gap/overlap at {at}: chunk starts {}", r.start));
                }
                if n > 0 && r.is_empty() {
                    return Err(format!("empty chunk {r:?} with n = {n}"));
                }
                at = r.end;
            }
            if at != n {
                return Err(format!("chunks cover 0..{at}, want 0..{n}"));
            }
            // near-equal sizes: largest and smallest differ by at most 1
            if n > 0 {
                let lens: Vec<usize> = rs.iter().map(|r| r.len()).collect();
                let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                if hi - lo > 1 {
                    return Err(format!("uneven chunks {lens:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pooled_run_chunks_and_map_match_sequential() {
    forall(
        22,
        60,
        |g| (g.dim0(48), g.threads(10), g.rng.below(1000) as u64),
        |&(n, threads, salt)| {
            let f = |i: usize| (i as u64).wrapping_mul(31).wrapping_add(salt);
            // map: per-item results in item order
            let got = pool::map(n, threads, f);
            let want: Vec<u64> = (0..n).map(f).collect();
            if got != want {
                return Err(format!("map({n}, {threads}) diverged from sequential"));
            }
            // run_chunks: per-chunk results equal an inline fold of the
            // same ranges, and equal the scoped (per-call spawn) oracle
            let pooled = pool::run_chunks(n, threads, |r| r.map(f).sum::<u64>());
            let inline: Vec<u64> = pool::chunk_ranges(n, threads)
                .into_iter()
                .map(|r| r.map(f).sum::<u64>())
                .collect();
            if pooled != inline {
                return Err(format!("run_chunks({n}, {threads}) diverged from inline"));
            }
            let scoped = pool::run_chunks_scoped(n, threads, |r| r.map(f).sum::<u64>());
            if pooled != scoped {
                return Err(format!("run_chunks({n}, {threads}) diverged from scoped"));
            }
            Ok(())
        },
    );
}

#[test]
fn pool_nested_use_does_not_deadlock() {
    // a pool call issued from inside a pool worker must complete (the
    // nesting rule runs it inline) and agree with the flat computation;
    // repeat enough times to cross lazy spawn and queue reuse
    for round in 0..16u64 {
        let inner_n = 8 + (round as usize % 5);
        let inner_sum: u64 = (0..inner_n as u64).map(|i| i * i + round).sum();
        let got = pool::run_chunks(6, 3, |outer| {
            let inner: u64 = pool::map(inner_n, 4, |i| (i as u64) * (i as u64) + round)
                .into_iter()
                .sum();
            outer.map(|i| i as u64).sum::<u64>() + inner
        });
        let want: Vec<u64> = pool::chunk_ranges(6, 3)
            .into_iter()
            .map(|r| r.map(|i| i as u64).sum::<u64>() + inner_sum)
            .collect();
        assert_eq!(got, want, "round {round}");
    }
}

fn exact_matmul(w: &[i8], k: usize, n: usize, x: &[f32]) -> Vec<f32> {
    let mut y = vec![0f32; n];
    for kk in 0..k {
        for j in 0..n {
            y[j] += x[kk] * w[kk * n + j] as f32;
        }
    }
    y
}

#[test]
fn prop_ideal_crossbar_mvm_equals_exact_matmul() {
    forall(
        11,
        30,
        |g| {
            let k = g.dim(600); // spans multi-tile when large
            let n = g.dim(300);
            let w = g.ternary_vec(k * n);
            let x = g.f32_vec(k, -2.0, 2.0);
            (k, n, w, x)
        },
        |(k, n, w, x)| {
            let wi: Vec<i8> = w.iter().map(|&v| v as i8).collect();
            let mut rng = Pcg64::new(99);
            let cim = CimMatrix::program(
                &wi,
                *k,
                *n,
                &DeviceConfig::ideal(),
                &ConverterConfig::ideal(),
                &mut rng,
            );
            let mut y = vec![0f32; *n];
            cim.mvm(x, &mut y, &mut rng);
            let want = exact_matmul(&wi, *k, *n, x);
            for (a, b) in y.iter().zip(&want) {
                if (a - b).abs() > 1e-2 {
                    return Err(format!("mvm {a} != exact {b}"));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// bit-packed ternary MVM: exact (==) against the f32 dense oracle
// ---------------------------------------------------------------------------

/// The f32 dense oracle for the packed kernel — column-ascending
/// accumulation, no zero skipping, the simplest possible reference.
fn dense_oracle(w: &[i8], k: usize, n: usize, x: &[f32], m: usize) -> Vec<f32> {
    let mut y = vec![0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            for j in 0..n {
                y[i * n + j] += x[i * k + kk] * w[kk * n + j] as f32;
            }
        }
    }
    y
}

#[test]
fn prop_packed_mvm_equals_dense_oracle_bit_for_bit() {
    // random shapes spanning the word-size corners: K < 64, K % 64 != 0,
    // multi-word K, empty matrices (k = 0 and n = 0), all-zero rows and
    // columns — integer activations, compared with ==, no tolerance
    forall(
        41,
        60,
        |g| {
            let k = g.dim0(200);
            let n = g.dim0(48);
            let m = 1 + g.rng.below(4);
            let mut w: Vec<f32> = g.ternary_vec(k * n);
            if k > 0 && n > 0 {
                // force an all-zero row and an all-zero column
                let zr = g.rng.below(k);
                let zc = g.rng.below(n);
                for j in 0..n {
                    w[zr * n + j] = 0.0;
                }
                for kk in 0..k {
                    w[kk * n + zc] = 0.0;
                }
            }
            let x = g.int_vec(m * k, -20, 20);
            (k, n, m, w, x)
        },
        |(k, n, m, w, x)| {
            let wi: Vec<i8> = w.iter().map(|&v| v as i8).collect();
            let pt = PackedTernary::pack(&wi, *k, *n);
            let got = pt.matmul(x, *m);
            let want = dense_oracle(&wi, *k, *n, x, *m);
            if got != want {
                return Err(format!("packed != dense oracle: {got:?} vs {want:?}"));
            }
            // the production dense kernel (4-wide unroll, zero skipping)
            // is an equally exact oracle on integer inputs
            if got != ops::matmul(x, w, *m, *k, *n) {
                return Err("packed != ops::matmul".into());
            }
            Ok(())
        },
    );
}

#[test]
fn packed_kernel_k_boundary_sweep_is_exact() {
    // deterministic sweep of the tail-masking corners around the u64
    // word size, plus the degenerate shapes
    let mut rng = Pcg64::new(42);
    for &k in &[0usize, 1, 3, 63, 64, 65, 127, 128, 129, 200] {
        for &n in &[0usize, 1, 7] {
            let wi: Vec<i8> = (0..k * n).map(|_| [-1i8, 0, 1][rng.below(3)]).collect();
            let pt = PackedTernary::pack(&wi, k, n);
            let x: Vec<f32> = (0..k).map(|_| (rng.below(31) as i64 - 15) as f32).collect();
            let mut y = vec![0f32; n];
            pt.mvm(&x, &mut y);
            assert_eq!(y, dense_oracle(&wi, k, n, &x, 1), "k={k} n={n}");
        }
    }
}

#[test]
fn prop_packed_integer_rows_take_the_popcount_path() {
    // the sign/magnitude plane decomposition must accept exactly the
    // rows the exactness contract covers, and the AND+popcount result
    // must match the select path and the oracle
    forall(
        43,
        40,
        |g| {
            let k = g.dim(180);
            let n = g.dim(24);
            (k, n, g.ternary_vec(k * n), g.int_vec(k, -100, 100))
        },
        |(k, n, w, x)| {
            if ActivationPlanes::try_pack(x).is_none() {
                return Err("integer row rejected by plane packing".into());
            }
            let wi: Vec<i8> = w.iter().map(|&v| v as i8).collect();
            let pt = PackedTernary::pack(&wi, *k, *n);
            let mut y = vec![0f32; *n];
            pt.mvm(x, &mut y);
            if y != dense_oracle(&wi, *k, *n, x, 1) {
                return Err("popcount path != dense oracle".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_packed_exactness_gate_is_overflow_safe_and_conservative() {
    // long rows x large magnitudes: the len * max|x| product is computed
    // with checked_mul, and whenever the gate accepts, the popcount path
    // must still equal the dense oracle bit for bit; whenever len * max
    // exceeds the 2^24 bound the row must route dense (try_pack None)
    forall(
        46,
        30,
        |g| {
            let k = 512 + g.rng.below(3584); // long rows: 512..4096
            let n = 1 + g.rng.below(8);
            let mag_bits = 8 + g.rng.below(16); // magnitudes up to 2^23
            let mag = 1i64 << mag_bits;
            let mut x = g.int_vec(k, -3, 3);
            // plant one entry at the big magnitude so max|x| is known
            let at = g.rng.below(k);
            x[at] = mag as f32;
            (k, n, g.ternary_vec(k * n), x, mag)
        },
        |(k, n, w, x, mag)| {
            let packs = ActivationPlanes::try_pack(x).is_some();
            let over = match (*k as u64).checked_mul(*mag as u64) {
                Some(p) => p > 1 << 24,
                None => true,
            };
            if packs == over {
                return Err(format!(
                    "gate mismatch: k={k} max={mag} packed={packs} over_bound={over}"
                ));
            }
            let wi: Vec<i8> = w.iter().map(|&v| v as i8).collect();
            let pt = PackedTernary::pack(&wi, *k, *n);
            let mut y = vec![0f32; *n];
            pt.mvm(x, &mut y);
            if packs && y != dense_oracle(&wi, *k, *n, x, 1) {
                return Err("accepted row diverged from the dense oracle".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_packed_float_path_stays_within_parity_tolerance() {
    // general f32 activations take the select path: not bit-exact by
    // contract, but inside the 1e-4 backend-parity envelope that gates
    // the xla-vs-native suite
    forall(
        44,
        40,
        |g| {
            let k = g.dim(180);
            let n = g.dim(24);
            (k, n, g.ternary_vec(k * n), g.f32_vec(k, -2.0, 2.0))
        },
        |(k, n, w, x)| {
            let wi: Vec<i8> = w.iter().map(|&v| v as i8).collect();
            let pt = PackedTernary::pack(&wi, *k, *n);
            let mut y = vec![0f32; *n];
            pt.mvm(x, &mut y);
            let want = dense_oracle(&wi, *k, *n, x, 1);
            for (a, b) in y.iter().zip(&want) {
                if (a - b).abs() > 1e-4 * (1.0 + b.abs()) {
                    return Err(format!("float path {a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ideal_cim_mean_path_is_packed_and_exact() {
    // the CIM mean path on an ideal device dispatches through the packed
    // kernel and still equals the exact matmul bit for bit on integers
    forall(
        45,
        20,
        |g| {
            let k = g.dim(600);
            let n = g.dim(300);
            (k, n, g.ternary_vec(k * n), g.int_vec(k, -10, 10))
        },
        |(k, n, w, x)| {
            let wi: Vec<i8> = w.iter().map(|&v| v as i8).collect();
            let mut rng = Pcg64::new(107);
            let cim = CimMatrix::program(
                &wi,
                *k,
                *n,
                &DeviceConfig::ideal(),
                &ConverterConfig::ideal(),
                &mut rng,
            );
            if !cim.is_packed() {
                return Err("ideal programming must build the packed form".into());
            }
            if cim.matmul_mean(x, 1) != dense_oracle(&wi, *k, *n, x, 1) {
                return Err("packed mean path != dense oracle".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cam_top1_is_exact_cosine_argmax() {
    forall(
        12,
        30,
        |g| {
            let classes = 2 + g.rng.below(10);
            let dim = g.dim(64).max(2);
            let mut centers = g.ternary_vec(classes * dim);
            for c in 0..classes {
                centers[c * dim] = 1.0; // no all-zero centers
            }
            let sv = g.f32_vec(dim, -1.5, 1.5);
            (classes, dim, centers, sv)
        },
        |(classes, dim, centers, sv)| {
            let ci: Vec<i8> = centers.iter().map(|&v| v as i8).collect();
            let mut rng = Pcg64::new(7);
            let bank = CamBank::program(
                &ci,
                *classes,
                *dim,
                &DeviceConfig::ideal(),
                &ConverterConfig::ideal(),
                &mut rng,
            );
            let got = bank.search(sv, &mut rng);
            // exact argmax
            let mut best = (f32::NEG_INFINITY, 0usize);
            for c in 0..*classes {
                let row = &centers[c * dim..(c + 1) * dim];
                let dot: f32 = row.iter().zip(sv).map(|(a, b)| a * b).sum();
                let nc: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
                let ns: f32 = sv.iter().map(|v| v * v).sum::<f32>().sqrt();
                let sim = if nc > 0.0 && ns > 0.0 {
                    dot / (nc * ns)
                } else {
                    0.0
                };
                if sim > best.0 {
                    best = (sim, c);
                }
            }
            // tolerate exact ties
            if got.class != best.1 && (got.similarity - best.0).abs() > 1e-5 {
                return Err(format!(
                    "cam chose {} (sim {}), exact argmax {} (sim {})",
                    got.class, got.similarity, best.1, best.0
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_exit_monotonicity_in_thresholds() {
    // raising any threshold can only push exits later (or keep them equal)
    forall(
        13,
        40,
        |g| {
            let exits = 2 + g.rng.below(6);
            let samples = 5 + g.rng.below(40);
            let mut trace = ExitTrace::new(exits);
            for s in 0..samples {
                let sims = g.f32_vec(exits, 0.0, 1.0);
                let preds: Vec<u16> =
                    (0..exits).map(|_| g.rng.below(10) as u16).collect();
                trace.push(&sims, &preds, (s % 10) as u16, (s % 10) as u16);
            }
            let lo = g.f32_vec(exits, 0.2, 0.9);
            let bump: Vec<f32> = lo
                .iter()
                .map(|&v| v + g.rng.uniform_in(0.0, 0.3) as f32)
                .collect();
            (trace, lo, bump)
        },
        |(trace, lo, hi)| {
            let e_lo = trace.evaluate(lo);
            let e_hi = trace.evaluate(hi);
            for (a, b) in e_lo.exits.iter().zip(&e_hi.exits) {
                if b < a {
                    return Err(format!("exit moved earlier: {a} -> {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_budget_drop_antitone_in_exit_depth() {
    forall(
        14,
        40,
        |g| {
            let blocks = 2 + g.rng.below(8);
            let ops: Vec<f64> = (0..blocks)
                .map(|_| g.rng.uniform_in(1e4, 1e6))
                .collect();
            let n = 5 + g.rng.below(30);
            let exits: Vec<usize> = (0..n).map(|_| g.rng.below(blocks)).collect();
            let deeper: Vec<usize> = exits
                .iter()
                .map(|&e| (e + g.rng.below(blocks - e)).min(blocks - 1))
                .collect();
            (ops, exits, deeper)
        },
        |(ops, exits, deeper)| {
            let dims = vec![8; ops.len()];
            let m = BudgetModel::new(ops.clone(), &dims, 10);
            let a = m.summarize(exits).budget_drop;
            let b = m.summarize(deeper).budget_drop;
            if b > a + 1e-9 {
                return Err(format!("deeper exits increased budget drop {a} -> {b}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip_numeric_arrays() {
    forall(
        15,
        50,
        |g| {
            let n = g.dim(30);
            g.f32_vec(n, -1e4, 1e4)
        },
        |xs| {
            let j = memdyn::util::json::arr_f64(
                &xs.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            );
            let text = j.to_string();
            let back = Json::parse(&text).map_err(|e| e.to_string())?;
            let got = back.f64_vec().ok_or("not an array")?;
            if got.len() != xs.len() {
                return Err("length changed".into());
            }
            for (a, b) in xs.iter().zip(&got) {
                if ((*a as f64) - b).abs() > 1e-3 * (1.0 + b.abs()) {
                    return Err(format!("{a} != {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_im2col_conserves_mass_for_ones_kernel() {
    // sum over conv output with an all-ones 1x1 kernel == sum over input
    forall(
        16,
        30,
        |g| {
            let hw = 2 + g.rng.below(12);
            let c = 1 + g.rng.below(6);
            let x = g.f32_vec(hw * hw * c, -1.0, 1.0);
            (hw, c, x)
        },
        |(hw, c, x)| {
            let (cols, ho, wo) = ops::im2col(x, 1, *hw, *hw, *c, 1, 1, 1);
            if (ho, wo) != (*hw, *hw) {
                return Err("1x1 stride-1 must preserve geometry".into());
            }
            let a: f32 = cols.iter().sum();
            let b: f32 = x.iter().sum();
            if (a - b).abs() > 1e-3 {
                return Err(format!("mass changed {a} vs {b}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_softmax_is_distribution() {
    forall(
        17,
        40,
        |g| {
            let rows = 1 + g.rng.below(5);
            let c = 2 + g.rng.below(12);
            (rows, c, g.f32_vec(rows * c, -30.0, 30.0))
        },
        |(rows, c, x)| {
            let mut y = x.clone();
            ops::softmax(&mut y, *rows, *c);
            for r in 0..*rows {
                let s: f32 = y[r * c..(r + 1) * c].iter().sum();
                if (s - 1.0).abs() > 1e-4 {
                    return Err(format!("row {r} sums to {s}"));
                }
                if y[r * c..(r + 1) * c].iter().any(|&v| !(0.0..=1.0).contains(&v)) {
                    return Err("probability outside [0,1]".into());
                }
            }
            Ok(())
        },
    );
}
