//! Integration tests over the real AOT artifacts: the interpreter-backed
//! runtime, the XLA-vs-native numerical parity, and end-to-end early-exit
//! accuracy.  (Stricter interpreter conformance lives in
//! `tests/hlo_interpreter.rs`.)
//!
//! These need `make artifacts` to have run; they are skipped (with a
//! message) when the artifacts directory is missing so `cargo test` stays
//! green on a fresh checkout.  With the native HLO interpreter in place,
//! `Runtime::cpu()` always succeeds, so every XLA-gated test executes for
//! real once the artifacts exist.

use std::path::PathBuf;

use memdyn::coordinator::dynmodel::{
    DynModel, NativeResNetModel, XlaPointNetModel, XlaResNetModel,
};
use memdyn::coordinator::{CenterSource, Engine, ExitMemory};
use memdyn::model::{DatasetBundle, ModelBundle};
use memdyn::nn::resnet::WeightSource;
use memdyn::nn::{NativeResNet, NoiseSpec};
use memdyn::runtime::{Runtime, TensorIn};
use memdyn::util::bin_io::Bundle;
use memdyn::util::rng::{Pcg64, StreamKey};

fn artifacts() -> Option<PathBuf> {
    // resolves MEMDYN_ARTIFACTS, then ./artifacts, then ../artifacts
    // (cargo runs tests with cwd = rust/, artifacts live at the repo root)
    let p = memdyn::model::artifacts_dir(None);
    if p.join("index.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: no artifacts at {p:?} (run `make artifacts`)");
        None
    }
}

/// The artifact runtime (kept as an Option so a future backend swap that
/// can fail at construction degrades back to a skip, not a panic).
fn runtime() -> Option<Runtime> {
    match Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: {e}");
            None
        }
    }
}

#[test]
fn runtime_executes_cim_smoke_kernel() {
    let Some(dir) = artifacts() else { return };
    let Some(rt) = runtime() else { return };
    let exe = rt.load(&dir.join("kernels/cim_smoke.hlo.txt")).unwrap();
    let b = Bundle::load(&dir.join("kernels/cim_smoke")).unwrap();
    let (wshape, w) = b.f32("w").unwrap();
    let (k, n) = (wshape[0], wshape[1]);
    let m = 16usize;
    let x: Vec<f32> = (0..m * k).map(|i| ((i % 7) as f32 - 3.0) / 3.0).collect();
    let out = exe
        .run(&[TensorIn {
            data: &x,
            shape: &[m, k],
        }])
        .unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), m * n);
    // compare with plain matmul: the Pallas kernel in the artifact must be
    // numerically the ternary matmul
    let want = memdyn::nn::ops::matmul(&x, &w, m, k, n);
    for (a, b) in out[0].iter().zip(&want) {
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
}

#[test]
fn xla_resnet_matches_native_digital_forward() {
    let Some(dir) = artifacts() else { return };
    let bundle = ModelBundle::load(&dir, "resnet").unwrap();
    let data = DatasetBundle::load(&dir, "mnist").unwrap();
    let Some(rt) = runtime() else { return };
    let xla = XlaResNetModel::load(&rt, &bundle).unwrap();
    let mut rng = Pcg64::new(1);
    let native = NativeResNet::build(
        &bundle,
        WeightSource::Ternary,
        &NoiseSpec::Digital,
        &mut rng,
    )
    .unwrap();

    let batch = 3usize;
    let input = &data.x_test[..batch * data.sample_len];

    // native forward (digital substrate: noise keys are ignored)
    let feat = memdyn::nn::resnet::image_feature(input, batch, 28).unwrap();
    let keys: Vec<StreamKey> = (0..batch as u64)
        .map(|i| StreamKey::root(1).child(i))
        .collect();
    let (nat_logits, nat_svs) = native.forward(&feat, &keys);

    // xla forward through the DynModel interface
    let mut state = xla.init_seq(input, batch, 0).unwrap();
    let mut xla_svs = Vec::new();
    for i in 0..xla.n_blocks() {
        xla_svs.push(xla.step(i, &mut state).unwrap());
    }
    let xla_logits = xla.finish(&state).unwrap();

    for (i, (nsv, xsv)) in nat_svs.iter().zip(&xla_svs).enumerate() {
        assert_eq!(nsv.len(), xsv.len(), "sv length at block {i}");
        for (a, b) in nsv.iter().zip(xsv) {
            assert!(
                (a - b).abs() < 2e-2,
                "block {i}: native {a} vs xla {b}"
            );
        }
    }
    for (a, b) in nat_logits.iter().zip(&xla_logits) {
        assert!((a - b).abs() < 5e-2, "logits: native {a} vs xla {b}");
    }
}

#[test]
fn xla_resnet_early_exit_accuracy_on_test_slice() {
    let Some(dir) = artifacts() else { return };
    let bundle = ModelBundle::load(&dir, "resnet").unwrap();
    let data = DatasetBundle::load(&dir, "mnist").unwrap();
    let Some(rt) = runtime() else { return };
    let xla = XlaResNetModel::load(&rt, &bundle).unwrap();
    let memory =
        ExitMemory::build(&bundle, CenterSource::TernaryQ, &NoiseSpec::Digital, 7)
            .unwrap();
    // tune thresholds on a train-split trace (cached to thresholds.json)
    let budget = memdyn::budget::BudgetModel::new(
        bundle.block_ops.clone(),
        &bundle.exit_dims,
        bundle.classes,
    );
    let calib_engine = memdyn::figures::common::resnet_engine(
        &bundle,
        memdyn::figures::common::Variant::EeQun,
        11,
    )
    .unwrap();
    let calib =
        memdyn::figures::common::trace_train(&calib_engine, &data, 400, 25).unwrap();
    let thr =
        memdyn::figures::common::tuned_thresholds(&bundle, &calib, &budget, 400)
            .unwrap();
    let engine = Engine::new(xla, memory, thr.values);
    let n = 100.min(data.n_test());
    let input = &data.x_test[..n * data.sample_len];
    let out = engine.infer_batch(input, n).unwrap();
    let correct = out
        .iter()
        .zip(&data.y_test[..n])
        .filter(|(o, &y)| o.class == y as usize)
        .count();
    let acc = correct as f64 / n as f64;
    // early exits on the synthetic-hard split trade some accuracy for
    // budget (EXPERIMENTS.md §Deviations); 0.75 is the regression gate
    assert!(acc > 0.72, "early-exit accuracy {acc} too low");
    // at least some samples should exit early at threshold 0.9
    assert!(out.iter().any(|o| o.exited_early));
}

#[test]
fn xla_resnet_bucket_padding_consistency() {
    // the same sample must classify identically at batch 1 and batch 5
    let Some(dir) = artifacts() else { return };
    let bundle = ModelBundle::load(&dir, "resnet").unwrap();
    let data = DatasetBundle::load(&dir, "mnist").unwrap();
    let Some(rt) = runtime() else { return };
    let xla = XlaResNetModel::load(&rt, &bundle).unwrap();
    let sl = data.sample_len;
    let mut s1 = xla.init_seq(&data.x_test[..sl], 1, 0).unwrap();
    let mut s5 = xla.init_seq(&data.x_test[..5 * sl], 5, 0).unwrap();
    let sv1 = xla.step(0, &mut s1).unwrap();
    let sv5 = xla.step(0, &mut s5).unwrap();
    let dim = sv1.len();
    for (a, b) in sv1.iter().zip(&sv5[..dim]) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn xla_pointnet_forward_runs_and_classifies() {
    let Some(dir) = artifacts() else { return };
    let bundle = ModelBundle::load(&dir, "pointnet").unwrap();
    let data = DatasetBundle::load(&dir, "modelnet").unwrap();
    let Some(rt) = runtime() else { return };
    let xla = XlaPointNetModel::load(&rt, &bundle).unwrap();
    let n = 8usize;
    let input = &data.x_test[..n * data.sample_len];
    let mut state = xla.init_seq(input, n, 0).unwrap();
    for i in 0..xla.n_blocks() {
        let svs = xla.step(i, &mut state).unwrap();
        assert_eq!(svs.len(), n * bundle.exit_dims[i], "sv shape at SA {i}");
        assert!(svs.iter().all(|v| v.is_finite()));
    }
    let logits = xla.finish(&state).unwrap();
    assert_eq!(logits.len(), n * bundle.classes);
    let correct = (0..n)
        .filter(|&i| {
            let row = &logits[i * bundle.classes..(i + 1) * bundle.classes];
            memdyn::util::stats::argmax(row) == Some(data.y_test[i] as usize)
        })
        .count();
    // ternary PointNet++ is the weakest model; just require better than chance
    assert!(correct >= 2, "only {correct}/{n} correct");
}

#[test]
fn mem_engine_bit_identical_across_thread_counts() {
    // the real Mem-variant engine must produce identical outcomes at 1, 2
    // and 8 threads for the same seed (per-request noise streams)
    let Some(dir) = artifacts() else { return };
    let bundle = ModelBundle::load(&dir, "resnet").unwrap();
    let data = DatasetBundle::load(&dir, "mnist").unwrap();
    let n = 12usize.min(data.n_test());
    let input = &data.x_test[..n * data.sample_len];
    let mk = |threads: usize| {
        let mut e = memdyn::figures::common::resnet_engine(
            &bundle,
            memdyn::figures::common::Variant::Mem,
            33,
        )
        .unwrap()
        .with_threads(threads);
        e.thresholds = vec![0.9; bundle.blocks];
        e
    };
    let want = mk(1).infer_batch(input, n).unwrap();
    for threads in [2usize, 8] {
        let got = mk(threads).infer_batch(input, n).unwrap();
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.class, b.class, "{threads} threads");
            assert_eq!(a.exit, b.exit, "{threads} threads");
            assert_eq!(a.exited_early, b.exited_early, "{threads} threads");
            assert!(
                a.similarity == b.similarity
                    || (a.similarity.is_nan() && b.similarity.is_nan()),
                "{threads} threads: {} vs {}",
                a.similarity,
                b.similarity
            );
        }
    }
}

#[test]
fn native_noisy_resnet_close_to_digital() {
    let Some(dir) = artifacts() else { return };
    let bundle = ModelBundle::load(&dir, "resnet").unwrap();
    let data = DatasetBundle::load(&dir, "mnist").unwrap();
    let n = 20usize;
    let mk_engine = |spec: NoiseSpec, seed: u64| {
        let mut rng = Pcg64::new(seed);
        let net =
            NativeResNet::build(&bundle, WeightSource::Ternary, &spec, &mut rng)
                .unwrap();
        let model = NativeResNetModel::new(net, bundle.classes, 28, seed);
        let memory =
            ExitMemory::build(&bundle, CenterSource::TernaryQ, &spec, seed).unwrap();
        Engine::new(model, memory, vec![0.9; bundle.blocks])
    };
    let digital = mk_engine(NoiseSpec::Digital, 3);
    // deployment-style programming (write-verify), as in the Mem variant
    let noisy = mk_engine(
        NoiseSpec::Analog {
            dev: memdyn::device::DeviceConfig::default().with_verify(0.04, 16),
            conv: memdyn::crossbar::ConverterConfig::default(),
        },
        3,
    );
    let input = &data.x_test[..n * data.sample_len];
    let dig_out = digital.infer_batch(input, n).unwrap();
    let noi_out = noisy.infer_batch(input, n).unwrap();
    let agree = dig_out
        .iter()
        .zip(&noi_out)
        .filter(|(a, b)| a.class == b.class)
        .count();
    // ternary quantization + write-verify is the noise defence: the clear
    // majority of predictions survive the full analogue chain
    assert!(agree >= n * 6 / 10, "only {agree}/{n} agree under noise");
}
