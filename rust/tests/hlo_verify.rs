//! Static-verification suite for the load-time HLO checker.
//!
//! Three layers, mirroring `src/hlo/verify.rs`:
//!
//! 1. **Malformed corpus** (`tests/data/bad_hlo/`) — every sample *parses*
//!    (the defect is semantic, not syntactic) and is then rejected by
//!    `Interpreter::new` with the expected typed `VerifyErrorKind`. Because
//!    `new` returns `Result`, a rejected module never yields an interpreter
//!    at all, so evaluation is unreachable by construction.
//! 2. **Plan mangles** — a clean module's compiled plan, corrupted through
//!    the public plan fields, must be caught by the independent plan pass.
//! 3. **Artifact sweep** (needs `make artifacts`) — every shipped module
//!    still verifies clean: zero rejects on real inputs.
//!
//! NOTE: nothing in this binary may call `verify::set_enabled(false)` —
//! tests run in parallel threads and a disabled gate would turn the
//! rejection assertions below into races. Ablation is exercised through
//! `Interpreter::new_unverified` instead (and, cross-process, by
//! `tests/determinism.rs`).

use std::path::PathBuf;

use memdyn::hlo::{parse, verify, Interpreter, VerifyError, VerifyErrorKind};

fn corpus_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/data/bad_hlo")
}

fn corpus(name: &str) -> String {
    let p = corpus_dir().join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading {p:?}: {e}"))
}

/// Parse a corpus sample (must succeed — the defect is semantic) and return
/// the typed error the load-time verifier rejects it with.
fn reject(name: &str) -> VerifyError {
    let module = parse(&corpus(name))
        .unwrap_or_else(|e| panic!("{name} must parse; its defect is semantic: {e:#}"));
    match Interpreter::new(module) {
        Ok(_) => panic!("{name} verified clean; expected a typed rejection"),
        Err(e) => e,
    }
}

// ---------------------------------------------------------------------------
// corpus: one test per sample, asserting the exact typed variant
// ---------------------------------------------------------------------------

#[test]
fn corpus_arity_rsqrt() {
    let e = reject("arity_rsqrt.hlo.txt");
    assert!(
        matches!(e.kind, VerifyErrorKind::BadArity { got: 2, .. }),
        "want BadArity with 2 operands, got {:?}",
        e.kind
    );
}

#[test]
fn corpus_dangling_tuple_ref() {
    let e = reject("dangling_tuple_ref.hlo.txt");
    assert!(
        matches!(e.kind, VerifyErrorKind::TupleIndexOutOfRange { index: 2, len: 2 }),
        "want TupleIndexOutOfRange{{2, 2}}, got {:?}",
        e.kind
    );
}

#[test]
fn corpus_dot_shape_mismatch() {
    let e = reject("dot_shape_mismatch.hlo.txt");
    assert!(
        matches!(e.kind, VerifyErrorKind::BadDotContraction { .. }),
        "want BadDotContraction, got {:?}",
        e.kind
    );
}

#[test]
fn corpus_cyclic_call() {
    let e = reject("cyclic_call.hlo.txt");
    assert!(
        matches!(e.kind, VerifyErrorKind::CyclicComputation { .. }),
        "want CyclicComputation, got {:?}",
        e.kind
    );
}

#[test]
fn corpus_dus_rank_mismatch() {
    let e = reject("dus_rank_mismatch.hlo.txt");
    assert!(
        matches!(e.kind, VerifyErrorKind::BadDusRank { .. }),
        "want BadDusRank, got {:?}",
        e.kind
    );
}

#[test]
fn corpus_while_sig_mismatch() {
    let e = reject("while_sig_mismatch.hlo.txt");
    assert!(
        matches!(e.kind, VerifyErrorKind::BadWhileSignature { .. }),
        "want BadWhileSignature, got {:?}",
        e.kind
    );
}

#[test]
fn corpus_comparator_arity() {
    let e = reject("comparator_arity.hlo.txt");
    assert!(
        matches!(e.kind, VerifyErrorKind::BadRegionSignature { .. }),
        "want BadRegionSignature, got {:?}",
        e.kind
    );
}

#[test]
fn corpus_binary_shape_mismatch() {
    let e = reject("binary_shape_mismatch.hlo.txt");
    assert!(
        matches!(e.kind, VerifyErrorKind::ShapeMismatch { .. }),
        "want ShapeMismatch, got {:?}",
        e.kind
    );
}

#[test]
fn corpus_transpose_bad_perm() {
    let e = reject("transpose_bad_perm.hlo.txt");
    assert!(
        matches!(e.kind, VerifyErrorKind::BadAttribute { .. }),
        "want BadAttribute, got {:?}",
        e.kind
    );
}

#[test]
fn corpus_reduce_odd_operands() {
    let e = reject("reduce_odd_operands.hlo.txt");
    assert!(
        matches!(e.kind, VerifyErrorKind::BadArity { .. }),
        "want BadArity, got {:?}",
        e.kind
    );
}

#[test]
fn corpus_select_dtype() {
    let e = reject("select_dtype.hlo.txt");
    assert!(
        matches!(e.kind, VerifyErrorKind::DTypeMismatch { .. }),
        "want DTypeMismatch, got {:?}",
        e.kind
    );
}

/// Every file in the corpus directory must be claimed by a test above, so a
/// new sample can't land without a typed-variant assertion.
#[test]
fn corpus_directory_matches_the_test_roster() {
    let mut on_disk: Vec<String> = std::fs::read_dir(corpus_dir())
        .expect("tests/data/bad_hlo must exist")
        .flatten()
        .filter_map(|e| {
            let n = e.file_name().to_string_lossy().into_owned();
            n.ends_with(".hlo.txt").then_some(n)
        })
        .collect();
    on_disk.sort();
    let mut roster = vec![
        "arity_rsqrt.hlo.txt",
        "binary_shape_mismatch.hlo.txt",
        "comparator_arity.hlo.txt",
        "cyclic_call.hlo.txt",
        "dangling_tuple_ref.hlo.txt",
        "dot_shape_mismatch.hlo.txt",
        "dus_rank_mismatch.hlo.txt",
        "reduce_odd_operands.hlo.txt",
        "select_dtype.hlo.txt",
        "transpose_bad_perm.hlo.txt",
        "while_sig_mismatch.hlo.txt",
    ];
    roster.sort();
    assert_eq!(on_disk, roster, "corpus files and test roster drifted apart");
}

// ---------------------------------------------------------------------------
// rejection semantics: errors carry the site, counters move, eval unreachable
// ---------------------------------------------------------------------------

#[test]
fn rejection_names_module_computation_and_instruction() {
    let e = reject("dangling_tuple_ref.hlo.txt");
    assert_eq!(e.module, "dangling_tuple_ref");
    assert_eq!(e.comp, "main.1");
    let msg = e.to_string();
    assert!(msg.contains("module dangling_tuple_ref"), "{msg}");
    assert!(msg.contains("tuple index 2 out of range"), "{msg}");
}

#[test]
fn rejections_bump_the_rejects_counter() {
    // Other tests in this binary reject modules concurrently, so only a
    // monotone lower bound is race-safe here.
    let before = verify::rejects_count();
    let _ = reject("binary_shape_mismatch.hlo.txt");
    assert!(verify::rejects_count() > before, "hlo.verify.rejects did not move");
}

#[test]
fn runtime_load_rejects_before_any_evaluation() {
    // Through the runtime front door the rejection surfaces at load time,
    // wrapped with the verification context — no Executable is ever built,
    // so `run` (and with it eval) is unreachable for this module.
    let err = memdyn::runtime::Executable::parse_text(
        &corpus("dangling_tuple_ref.hlo.txt"),
        PathBuf::from("dangling_tuple_ref.hlo.txt"),
    )
    .expect_err("malformed module must not produce an executable");
    let msg = format!("{err:#}");
    assert!(msg.contains("statically verifying"), "{msg}");
    assert!(msg.contains("tuple index 2 out of range"), "{msg}");
}

#[test]
fn ablation_path_loads_what_the_gate_rejects() {
    // `new_unverified` is the ablation hook: the same module that the
    // verifier rejects constructs fine without it (the extra rsqrt operand
    // is simply ignored by the evaluator), proving the rejection is the
    // verifier's judgement rather than a parser or planner failure.
    let module = parse(&corpus("arity_rsqrt.hlo.txt")).unwrap();
    let _interp = Interpreter::new_unverified(module);
}

// ---------------------------------------------------------------------------
// plan pass, through the public plan surface
// ---------------------------------------------------------------------------

const STRAIGHT_LINE: &str = r#"
HloModule straight

ENTRY main.1 {
  a.2 = f32[4]{0} parameter(0)
  b.3 = f32[4]{0} add(a.2, a.2)
  ROOT c.4 = f32[4]{0} multiply(b.3, b.3)
}
"#;

#[test]
fn mangled_drop_schedule_is_rejected() {
    let interp = Interpreter::new(parse(STRAIGHT_LINE).unwrap()).unwrap();
    let mut plan = interp.plan().clone();
    // Slot 0 (`a.2`) is dropped at step 1; dropping it again at the root
    // step violates the drop-exactly-once discipline.
    plan.comps[0].steps[2].drops.push(0);
    let e = verify::verify_plan(interp.module(), &plan)
        .expect_err("double drop must be rejected");
    assert!(
        matches!(e.kind, VerifyErrorKind::BadDrop { .. }),
        "want BadDrop, got {:?}",
        e.kind
    );
}

#[test]
fn mangled_region_sizing_is_rejected() {
    let interp = Interpreter::new(parse(STRAIGHT_LINE).unwrap()).unwrap();
    let mut plan = interp.plan().clone();
    let r = plan.comps[0].region_of[1];
    plan.comps[0].region_bytes[r] = 0;
    let e = verify::verify_plan(interp.module(), &plan)
        .expect_err("undersized region must be rejected");
    assert!(
        matches!(e.kind, VerifyErrorKind::RegionTooSmall { .. }),
        "want RegionTooSmall, got {:?}",
        e.kind
    );
}

// ---------------------------------------------------------------------------
// artifact sweep (needs `make artifacts`)
// ---------------------------------------------------------------------------

#[test]
fn all_shipped_artifacts_verify_clean_with_zero_rejects() {
    let dir = memdyn::model::artifacts_dir(None);
    if !dir.join("index.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} (run `make artifacts`)");
        return;
    }
    let mut files = 0usize;
    let mut rejected: Vec<String> = Vec::new();
    for sub in ["resnet", "pointnet", "kernels"] {
        let Ok(entries) = std::fs::read_dir(dir.join(sub)) else {
            continue;
        };
        for e in entries.flatten() {
            let p = e.path();
            if !p.to_string_lossy().ends_with(".hlo.txt") {
                continue;
            }
            let text = std::fs::read_to_string(&p).unwrap();
            let module =
                parse(&text).unwrap_or_else(|err| panic!("{p:?} failed to parse: {err:#}"));
            // Each `Ok` below is a load that contributed nothing to
            // `hlo.verify.rejects` (the counter moves only on `Err`), so an
            // empty `rejected` list is exactly the rejects == 0 claim —
            // stated per-call because parallel corpus tests move the global.
            if let Err(err) = Interpreter::new(module) {
                rejected.push(format!("{p:?}: {err}"));
            }
            files += 1;
        }
    }
    assert!(files >= 40, "only {files} HLO artifacts found");
    assert!(
        rejected.is_empty(),
        "verifier false-rejected shipped artifacts (rejects must stay 0):\n{}",
        rejected.join("\n")
    );
}
